"""Shared benchmark helpers."""

from __future__ import annotations

import time

import jax


def time_us(fn, *args, iters: int = 20) -> float:
    """Mean wall-clock microseconds per call over ``iters`` dispatches.

    One warmup dispatch absorbs jit compilation; ``jax.block_until_ready``
    handles scalar, tuple and pytree returns uniformly (a conditional
    double-call here once double-dispatched every warmup and skewed
    small-N numbers — keep it a single call).
    """
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
