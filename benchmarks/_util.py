"""Shared benchmark helpers."""

from __future__ import annotations

import statistics
import time

import jax

#: trials per measurement — every benchmark that records a ``time_us``
#: number also records this, so readers know the variance treatment.
DEFAULT_TRIALS = 5


def time_us(fn, *args, iters: int = 20, trials: int = DEFAULT_TRIALS) -> float:
    """Median over ``trials`` of mean wall-clock microseconds per call.

    Each trial times ``iters`` dispatches back to back; the reported
    number is the MEDIAN of the per-trial means.  A single mean was
    non-monotonic in problem size on shared CI hosts (one descheduled
    trial skewed the whole figure — BENCH_kernels.json once reported
    n=512 faster than n=256); the median discards those outlier trials.

    One warmup dispatch absorbs jit compilation; ``jax.block_until_ready``
    handles scalar, tuple and pytree returns uniformly (a conditional
    double-call here once double-dispatched every warmup and skewed
    small-N numbers — keep it a single call).
    """
    jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    return statistics.median(samples)


def timing_meta(iters: int, trials: int = DEFAULT_TRIALS) -> dict:
    """Provenance record benchmarks embed beside their timings."""
    return {"iters": iters, "trials": max(1, trials),
            "stat": "median_of_trial_means"}
