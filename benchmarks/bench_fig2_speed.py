"""Paper Figure 2: ACDC vs dense linear layer speed across layer sizes.

The paper benchmarks CUDA kernels on a Titan X.  Here we produce two views:

1. CPU wall-clock of the jitted jnp implementations (directional only —
   this container is not the target hardware);
2. the ANALYTIC TPU-v5e roofline times for each implementation variant,
   from the same byte/FLOP model the paper uses in section 5 (8N bytes/row
   fused vs 24N multi-call; DCT-as-matmul FLOPs vs FFT FLOPs) — the
   apples-to-apples replacement for the GPU plot.

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acdc as A

BATCH = 128
SIZES = (128, 256, 512, 1024, 2048, 4096)

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s


from benchmarks._util import time_us as _time


def roofline_acdc_us(n: int, batch: int, fused: bool) -> float:
    """Analytic TPU time for one ACDC layer application on a batch."""
    bytes_per_row = 8 * n if fused else 24 * n      # paper section 5
    flops_per_row = 4 * n + 2 * 2 * n * n / 1      # scale + 2 matmul-DCTs
    # matmul-DCT: 2*N^2 MACs * 2 transforms; memory-bound check vs MXU
    t_mem = batch * bytes_per_row / HBM_BW
    t_flop = batch * (4 * n + 4 * n * n) / PEAK_FLOPS
    return max(t_mem, t_flop) * 1e6


def roofline_dense_us(n: int, batch: int) -> float:
    t_mem = (4 * n * n + 8 * n * batch) / HBM_BW    # weight + io (fp32)
    t_flop = 2 * n * n * batch / PEAK_FLOPS
    return max(t_mem, t_flop) * 1e6


def main(csv=True):
    rows = []
    for n in SIZES:
        x = jax.random.normal(jax.random.PRNGKey(0), (BATCH, n))
        a = jnp.ones((n,))
        d = jnp.ones((n,))
        w = jax.random.normal(jax.random.PRNGKey(1), (n, n))

        acdc_fft = jax.jit(lambda x, a, d: A.acdc(x, a, d, method="fft"))
        acdc_mm = jax.jit(lambda x, a, d: A.acdc(x, a, d, method="matmul"))
        dense = jax.jit(lambda x, w: x @ w)

        t_fft = _time(acdc_fft, x, a, d)
        t_mm = _time(acdc_mm, x, a, d)
        t_dense = _time(dense, x, w)
        rows.append((f"fig2_acdc_fft_n{n}", t_fft,
                     f"cpu_speedup_vs_dense={t_dense / t_fft:.2f}x"))
        rows.append((f"fig2_acdc_matmul_n{n}", t_mm,
                     f"cpu_speedup_vs_dense={t_dense / t_mm:.2f}x"))
        rows.append((f"fig2_dense_n{n}", t_dense, ""))
        rows.append((f"fig2_tpu_roofline_acdc_fused_n{n}",
                     roofline_acdc_us(n, BATCH, fused=True),
                     f"tpu_speedup_vs_dense="
                     f"{roofline_dense_us(n, BATCH)/roofline_acdc_us(n, BATCH, True):.1f}x"))
        rows.append((f"fig2_tpu_roofline_dense_n{n}",
                     roofline_dense_us(n, BATCH), ""))
    if csv:
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]}")
    return rows


if __name__ == "__main__":
    main()
