"""Paper Figure 3: linear-operator recovery loss vs cascade depth K, under
the paper's good init N(1, sigma) and the standard init N(0, sigma).

Faithful setup (section 6.1): X in R^{10000 x 32} ~ U[0,1], W_true 32x32
~ U[0,1], Gaussian noise N(0, 1e-4) on targets; ACDC_K trained by gradient
descent.  CSV: name,us_per_call,derived (value column = final train MSE).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acdc as A

N = 32
KS = (1, 2, 4, 8, 16, 32)


def make_problem(m=10_000, seed=0):
    r = np.random.RandomState(seed)
    x = r.rand(m, N).astype(np.float32)
    w = r.rand(N, N).astype(np.float32)
    y = x @ w + np.sqrt(1e-4) * r.randn(m, N).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(w)


def train(cfg: A.ACDCConfig, x, y, steps=3000, lr0=2e-2, seed=0):
    p = A.init_acdc_params(jax.random.PRNGKey(seed), cfg)

    def loss_fn(p):
        return jnp.mean((A.acdc_cascade(p, x, cfg) - y) ** 2)

    @jax.jit
    def step(carry, i):
        p, m, v = carry
        lr = lr0 * 0.5 * (1 + jnp.cos(jnp.pi * i / steps))
        l, g = jax.value_and_grad(loss_fn)(p)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** (i + 1.0)), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** (i + 1.0)), v)
        p = jax.tree.map(lambda pp, a, b: pp - lr * a / (jnp.sqrt(b) + 1e-8),
                         p, mh, vh)
        return (p, m, v), l

    zeros = jax.tree.map(jnp.zeros_like, p)
    (p, _, _), losses = jax.lax.scan(step, (p, zeros, zeros),
                                     jnp.arange(steps, dtype=jnp.float32))
    return float(loss_fn(p)), losses


def main(csv=True, steps=3000):
    x, y, w = make_problem()
    floor = float(jnp.mean((y - x @ w) ** 2))
    rows = [("fig3_noise_floor", floor, "dense W_true residual")]
    for k in KS:
        t0 = time.time()
        good, _ = train(A.ACDCConfig(n=N, k=k, bias=True,
                                     init_mean=1.0, init_std=1e-1), x, y,
                        steps)
        bad, _ = train(A.ACDCConfig(n=N, k=k, bias=True,
                                    init_mean=0.0, init_std=1e-3), x, y,
                       steps)
        dt = time.time() - t0
        rows.append((f"fig3_k{k}_good_init", good,
                     f"init=N(1,1e-1) {dt:.0f}s"))
        rows.append((f"fig3_k{k}_bad_init", bad, "init=N(0,1e-3)"))
    if csv:
        for r in rows:
            print(f"{r[0]},{r[1]:.6f},{r[2]}")
    return rows


if __name__ == "__main__":
    main()
