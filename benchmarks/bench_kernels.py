"""Kernel-level benchmark for the fused ACDC training hot path.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--quick]

Two views, written to ``results/BENCH_kernels.json``:

1. **Analytic roofline bytes-per-row model** (the paper's section 5
   accounting, exact on any hardware): per-row HBM traffic of

   * the fused forward           (8N: read row + write row, fp32),
   * the per-layer order-K scan  (8KN: every layer round-trips HBM),
   * the whole-cascade fused fwd (8N, INDEPENDENT of K),
   * the old four-matmul XLA backward (48N: gc/h2/dh1 each round-trip),
   * the fused Pallas backward   (12N: read x + read g + write dx),
   * the reverse-sweep cascade backward (12N, INDEPENDENT of K — the
     cotangent stays VMEM-resident across all K layers) vs the
     per-layer HBM-remat scan backward (12KN + 8(K-1)N: K per-layer
     backward kernels plus the rematerialized layer inputs).

   Transform-matrix traffic is excluded: C/C^T are O(N^2) one-offs
   amortized over the batch in every variant equally.

2. **Wall-clock** of the real code paths on this host.  Timings route
   through ``benchmarks._util.time_us`` (median of trial means; the
   trial count is recorded in the JSON under ``timing``).  On CPU the
   kernels run in interpret mode: every timing entry is tagged
   ``non_roofline: true`` and NO roofline claim (e.g. backward
   flat-in-K) is asserted from them — those assertions only run on real
   device backends.  The analytic bytes model is asserted everywhere.

The bench also snapshots ``ops.CASCADE_BWD_DISPATCHES`` and FAILS if a
fused-regime cascade backward routed to the per-layer scan — the CI
regression gate for the reverse-sweep dispatch.

A ``cascade_families`` section runs the fused cascade (fwd + full VJP)
once per registered transform family (acdc / circulant / hadamard, see
``core/families.py``) and asserts the analytic bytes/row model is
family-invariant — the families swap the C/C^T operand contents, never
the kernel's memory behaviour.

A ``paged_attn`` section benches the serving-side fused paged-attention
kernel against the block-table gather on synthetic pool/table operands
(decode T=1 and verify T=3 grids) at a FIXED live length across growing
page tables, and asserts its analytic memory model: kernel bytes/slot a
function of length only (flat in MB) while gather bytes/slot scale with
MB, plus the same dispatch gate via ``ops.PAGED_ATTN_DISPATCHES``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks._util import DEFAULT_TRIALS, time_us as _time, timing_meta
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import ops
from repro.kernels import paged_attn

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

FP32 = 4  # bytes; the kernels' HBM-facing activation width in this repo

#: device wall-clock tolerance for the backward flat-in-K claim: K=8 may
#: cost at most this multiple of K=1 (FLOPs grow with K even at flat
#: bytes, so "flat" means bandwidth-flat, not FLOP-flat).
FLAT_IN_K_DEVICE_FACTOR = 3.0


def per_row_bytes(n: int, k: int = 1) -> dict:
    """Analytic per-row HBM bytes for each implementation variant."""
    return {
        "fwd_fused": 2 * FP32 * n,                 # 8N: x in, y out
        "fwd_per_layer_cascade": 2 * FP32 * n * k,  # 8KN: K round trips
        "fwd_cascade_fused": 2 * FP32 * n,          # 8N independent of K
        "bwd_four_matmul_xla": 12 * FP32 * n,       # 48N: x,g,dx + 3 inter-
                                                    # mediates x2 (wr+rd) +
                                                    # 3 reduction re-reads
        "bwd_fused": 3 * FP32 * n,                  # 12N: x, g in; dx out
        # Reverse sweep: x, g in; dx out — the K-deep stash lives in
        # VMEM, so HBM traffic is K-independent.
        "bwd_cascade_reverse_sweep": 3 * FP32 * n,
        # Per-layer scan backward: remat writes+reads K-1 layer inputs
        # (2 * 4N each) and each of K per-layer kernels moves 12N.
        "bwd_cascade_per_layer_scan": FP32 * n * (3 * k + 2 * (k - 1)),
    }


def bench_layer(n: int, m: int, iters: int, trials: int,
                non_roofline: bool) -> dict:
    r = jax.random.PRNGKey(n)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    g = jax.random.normal(jax.random.fold_in(r, 3), (m, n))

    fwd = jax.jit(ops.acdc_fused_nobias)

    @jax.jit
    def bwd(x, a, d, g):
        _, vjp = jax.vjp(ops.acdc_fused_nobias, x, a, d)
        return vjp(g)

    regime = "fused" if n <= fused_mod.MAX_FUSED_N else "two_call"
    return {
        "n": n, "rows": m, "regime": regime,
        "non_roofline": non_roofline,
        "fwd_us": _time(fwd, x, a, d, iters=iters, trials=trials),
        "bwd_us": _time(bwd, x, a, d, g, iters=iters, trials=trials),
        "roofline_bytes_per_row": per_row_bytes(n),
    }


def _cascade_operands(n: int, k: int, m: int):
    r = jax.random.PRNGKey(100 + k)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    g = jax.random.normal(jax.random.fold_in(r, 3), (m, n))
    return x, a, d, g


def bench_cascade(n: int, k: int, m: int, iters: int, trials: int,
                  non_roofline: bool) -> dict:
    x, a, d, _ = _cascade_operands(n, k, m)

    fused = jax.jit(lambda x, a, d: ops.acdc_cascade_op(
        x, a, d, relu=True, permute=True))
    per_layer = jax.jit(lambda x, a, d: ops._cascade_per_layer(
        x, a, d, None, True, True))

    rb = per_row_bytes(n, k)
    return {
        "n": n, "k": k, "rows": m,
        "non_roofline": non_roofline,
        "cascade_fused_fwd_us": _time(fused, x, a, d, iters=iters,
                                      trials=trials),
        "cascade_per_layer_fwd_us": _time(per_layer, x, a, d, iters=iters,
                                          trials=trials),
        "roofline_bytes_per_row": {
            "fused": rb["fwd_cascade_fused"],
            "per_layer": rb["fwd_per_layer_cascade"],
        },
    }


def bench_cascade_family(family: str, n: int, k: int, m: int, iters: int,
                         trials: int, non_roofline: bool) -> dict:
    """Per-family whole-cascade fwd + full VJP wall-clock.

    The analytic bytes/row model is family-INVARIANT: every registered
    family feeds the same kernel bodies the same-shaped C/C^T operands,
    so per-row HBM traffic is identical — only the matrix contents (and
    thus any device-side sparsity/compiler luck) differ.  The bench
    records that invariance explicitly.
    """
    from repro.core import families as families_mod

    n = families_mod.get_family(family).valid_size(n)
    x, a, d, g = _cascade_operands(n, k, m)

    fwd = jax.jit(lambda x, a, d: ops.acdc_cascade_op(
        x, a, d, relu=True, permute=True, family=family))

    @jax.jit
    def bwd(x, a, d, g):
        _, vjp = jax.vjp(lambda x, a, d: ops.acdc_cascade_op(
            x, a, d, relu=True, permute=True, family=family), x, a, d)
        return vjp(g)

    rb = per_row_bytes(n, k)
    return {
        "family": family, "n": n, "k": k, "rows": m,
        "non_roofline": non_roofline,
        "cascade_fused_fwd_us": _time(fwd, x, a, d, iters=iters,
                                      trials=trials),
        "cascade_bwd_us": _time(bwd, x, a, d, g, iters=iters,
                                trials=trials),
        "roofline_bytes_per_row": {
            "fwd_fused": rb["fwd_cascade_fused"],
            "bwd_reverse_sweep": rb["bwd_cascade_reverse_sweep"],
        },
    }


def bench_cascade_bwd(n: int, k: int, m: int, iters: int, trials: int,
                      non_roofline: bool) -> dict:
    """Full cascade VJP (dx + all diagonal grads), reverse sweep vs the
    per-layer HBM-remat scan it replaced."""
    x, a, d, g = _cascade_operands(n, k, m)

    @jax.jit
    def bwd_reverse_sweep(x, a, d, g):
        _, vjp = jax.vjp(lambda x, a, d: ops.acdc_cascade_op(
            x, a, d, relu=True, permute=True), x, a, d)
        return vjp(g)

    @jax.jit
    def bwd_per_layer_scan(x, a, d, g):
        return ops._cascade_bwd_core(True, True, x, a, d, None, g)

    rb = per_row_bytes(n, k)
    return {
        "n": n, "k": k, "rows": m,
        "non_roofline": non_roofline,
        "reverse_sweep_us": _time(bwd_reverse_sweep, x, a, d, g,
                                  iters=iters, trials=trials),
        "per_layer_scan_us": _time(bwd_per_layer_scan, x, a, d, g,
                                   iters=iters, trials=trials),
        "roofline_bytes_per_row": {
            "reverse_sweep": rb["bwd_cascade_reverse_sweep"],
            "per_layer_scan": rb["bwd_cascade_per_layer_scan"],
        },
    }


def paged_attn_bytes_per_slot(mb: int, bs: int, hkv: int, dh: int,
                              length: int, itemsize: int = 4) -> dict:
    """Analytic per-slot per-layer K/V bytes for one attention tick."""
    tok = hkv * dh * 2 * itemsize           # K and V
    return {
        "gather": mb * bs * tok,            # whole virtual row, any fill
        "kernel": -(-length // bs) * bs * tok,  # mapped prefix pages only
    }


def bench_paged_attn(mb: int, t: int, iters: int, trials: int,
                     non_roofline: bool) -> dict:
    """Fused streaming kernel vs the block-table gather on synthetic
    serving operands: ``b`` slot rows over an ``mb``-page table, live
    length pinned at 2 pages so the streamed traffic is identical across
    the mb sweep while the gather's grows."""
    b, bs, hkv, group, dh = 4, 8, 4, 2, 32
    length = 2 * bs
    r = jax.random.PRNGKey(mb * 10 + t)
    q = jax.random.normal(r, (b, t, hkv * group, dh))
    knew = jax.random.normal(jax.random.fold_in(r, 1), (b, t, hkv, dh))
    vnew = jax.random.normal(jax.random.fold_in(r, 2), (b, t, hkv, dh))
    nb = b * mb
    kp = jax.random.normal(jax.random.fold_in(r, 3), (nb + 1, bs, hkv, dh))
    vp = jax.random.normal(jax.random.fold_in(r, 4), (nb + 1, bs, hkv, dh))
    tbl = jnp.arange(nb, dtype=jnp.int32).reshape(b, mb)
    pos = jnp.full((b,), length, jnp.int32)
    win = jnp.int32(0)

    was_forced = paged_attn.FORCE_FUSED
    paged_attn.FORCE_FUSED = True
    try:
        blk = ops.paged_attn_route(hkv, dh, group, t, bs, jnp.float32)
    finally:
        paged_attn.FORCE_FUSED = was_forced
    pc, bh = blk

    fused = jax.jit(lambda *a: paged_attn.paged_attention(
        *a, softcap=0.0, page_chunk=pc, head_block=bh,
        interpret=non_roofline))

    virtual = mb * bs

    @jax.jit
    def gather(q, knew, vnew, kp, vp, tbl, pos, win):
        qpos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        blk_i = jnp.minimum(qpos // bs, mb - 1)
        phys = jnp.take_along_axis(tbl, blk_i, axis=1)
        ok = jnp.logical_and(phys >= 0, qpos < virtual)
        phys = jnp.where(ok, phys, nb)
        kp = kp.at[phys, qpos % bs].set(knew)
        vp = vp.at[phys, qpos % bs].set(vnew)
        rt = jnp.where(tbl >= 0, tbl, 0)
        ck = kp[rt].reshape(b, virtual, hkv, dh)
        cv = vp[rt].reshape(b, virtual, hkv, dh)
        kpos = jnp.arange(virtual, dtype=jnp.int32)[None, None, :]
        mask = kpos <= qpos[:, :, None]
        qg = q.reshape(b, t, hkv, group, dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck) * dh ** -0.5
        s = jnp.where(mask[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, cv)
        return o.reshape(b, t, hkv * group, dh), kp, vp

    args = (q, knew, vnew, kp, vp, tbl, pos, win)
    return {
        "mb": mb, "t": t, "rows": b, "block_size": bs, "length": length,
        "block": [pc, bh],
        "non_roofline": non_roofline,
        "fused_us": _time(fused, *args, iters=iters, trials=trials),
        "gather_us": _time(gather, *args, iters=iters, trials=trials),
        "bytes_per_slot": paged_attn_bytes_per_slot(mb, bs, hkv, dh,
                                                    length),
    }


def _assert_paged_attn_claims(out: dict, dispatch_before: dict) -> None:
    """Paged-attention acceptance gates, mirroring the cascade ones.

    * analytic: kernel bytes/slot below gather's and CONSTANT across the
      mb sweep (fixed length), gather bytes/slot growing with mb —
      asserted on every backend;
    * dispatch: every bench row must have routed fused, none to gather.
    """
    rows = out["paged_attn"]
    kernel_bytes = {r["bytes_per_slot"]["kernel"] for r in rows}
    assert len(kernel_bytes) == 1, (
        f"kernel bytes/slot must be mb-independent: {kernel_bytes}")
    by_mb = sorted({r["mb"]: r["bytes_per_slot"]["gather"]
                    for r in rows}.items())
    gather_bytes = [g for _, g in by_mb]
    assert gather_bytes == sorted(gather_bytes) and \
        gather_bytes[0] < gather_bytes[-1], (
        f"gather bytes/slot must grow with mb: {by_mb}")
    assert min(gather_bytes) > kernel_bytes.pop()

    delta = {key: ops.PAGED_ATTN_DISPATCHES[key] - dispatch_before[key]
             for key in ops.PAGED_ATTN_DISPATCHES}
    out["paged_attn_dispatches"] = delta
    if delta["fused"] < len(rows) or delta["gather"] > 0:
        raise SystemExit(
            "paged attention dispatch regressed to the gather path: "
            f"{delta} over {len(rows)} benches")


def _assert_cascade_bwd_claims(out: dict, dispatch_before: dict) -> None:
    """The acceptance checks this bench exists to gate.

    * analytic: reverse-sweep bytes/row identical for every K (the scan
      model must grow) — asserted on every backend;
    * dispatch: every fused-regime cascade backward traced here must
      have routed to the reverse sweep, none to the per-layer scan;
    * wall-clock flat-in-K: device backends only (interpret-mode CPU
      timings are non-roofline and prove nothing about HBM).
    """
    rows = out["cascade_bwd"]
    sweep_bytes = {r["roofline_bytes_per_row"]["reverse_sweep"]
                   for r in rows}
    assert len(sweep_bytes) == 1, (
        f"reverse-sweep bytes/row must be K-independent: {sweep_bytes}")
    scan_bytes = [r["roofline_bytes_per_row"]["per_layer_scan"]
                  for r in rows]
    assert scan_bytes == sorted(scan_bytes) and scan_bytes[0] < scan_bytes[-1], (
        f"per-layer scan bytes/row must grow with K: {scan_bytes}")

    delta = {key: ops.CASCADE_BWD_DISPATCHES[key] - dispatch_before[key]
             for key in ops.CASCADE_BWD_DISPATCHES}
    out["cascade_bwd_dispatches"] = delta
    if delta["reverse_sweep"] < len(rows) or delta["per_layer_scan"] > 0:
        raise SystemExit(
            "cascade backward dispatch regressed to per-layer scan: "
            f"{delta} over {len(rows)} fused-regime benches")

    if not out["interpret_mode"]:
        by_k = sorted((r["k"], r["reverse_sweep_us"]) for r in rows)
        lo, hi = by_k[0][1], by_k[-1][1]
        assert hi <= FLAT_IN_K_DEVICE_FACTOR * lo, (
            f"device backward not flat in K: K={by_k[0][0]} -> {lo:.1f}us, "
            f"K={by_k[-1][0]} -> {hi:.1f}us")


def main(csv: bool = True, argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    iters = 2 if args.quick else 5
    trials = 3 if args.quick else DEFAULT_TRIALS
    m = 128 if args.quick else 256

    layer_sizes = (128, 256) if args.quick else (128, 256, 512)
    cascade_ks = (1, 2, 4) if args.quick else (1, 2, 4, 8)
    bwd_ks = tuple(k for k in cascade_ks if k >= 2)

    interpret = jax.default_backend() != "tpu"
    if interpret:
        print("WARNING: interpret-mode (CPU) timings — non-roofline, "
              "directional only; flat-in-K is asserted on device runs.")
    dispatch_before = dict(ops.CASCADE_BWD_DISPATCHES)

    out = {
        "backend": jax.default_backend(),
        "interpret_mode": interpret,
        "timing": timing_meta(iters, trials),
        "layers": [bench_layer(n, m, iters, trials, interpret)
                   for n in layer_sizes],
        "cascades": [bench_cascade(256, k, m, iters, trials, interpret)
                     for k in cascade_ks],
        # The training acceptance check: the reverse-sweep backward moves
        # 12N bytes/row for EVERY K, while the scan path scales with K.
        "cascade_bwd": [bench_cascade_bwd(256, k, m, iters, trials,
                                          interpret) for k in bwd_ks],
        "cascade_bytes_model": {
            str(k): per_row_bytes(256, k) for k in cascade_ks
        },
        # One fused cascade per registered transform family (same kernel
        # bodies, different C/C^T operands — bytes/row identical by
        # construction, wall-clock recorded per family).
        "cascade_families": [
            bench_cascade_family(fam, 256, 3, m, iters, trials, interpret)
            for fam in ("acdc", "circulant", "hadamard")
        ],
    }
    _assert_cascade_bwd_claims(out, dispatch_before)
    fam_bytes = {tuple(sorted(r["roofline_bytes_per_row"].items()))
                 for r in out["cascade_families"]}
    assert len(fam_bytes) == 1, (
        "family-invariant bytes/row model broke: " + repr(fam_bytes))

    paged_dispatch_before = dict(ops.PAGED_ATTN_DISPATCHES)
    paged_mbs = (4, 8) if args.quick else (4, 8, 16)
    out["paged_attn"] = [bench_paged_attn(mb, t, iters, trials, interpret)
                         for mb in paged_mbs for t in (1, 3)]
    out["paged_attn_bytes_model"] = {
        str(mb): paged_attn_bytes_per_slot(mb, 8, 4, 32, 16)
        for mb in paged_mbs
    }
    _assert_paged_attn_claims(out, paged_dispatch_before)

    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    if csv:
        for row in out["layers"]:
            print(f"kernels_fwd_n{row['n']},{row['fwd_us']:.2f},"
                  f"regime={row['regime']}")
            print(f"kernels_bwd_n{row['n']},{row['bwd_us']:.2f},"
                  f"roofline_bytes_row="
                  f"{row['roofline_bytes_per_row']['bwd_fused']}")
        for row in out["cascades"]:
            print(f"kernels_cascade_fused_k{row['k']},"
                  f"{row['cascade_fused_fwd_us']:.2f},"
                  f"bytes_row={row['roofline_bytes_per_row']['fused']}")
            print(f"kernels_cascade_per_layer_k{row['k']},"
                  f"{row['cascade_per_layer_fwd_us']:.2f},"
                  f"bytes_row={row['roofline_bytes_per_row']['per_layer']}")
        for row in out["cascade_families"]:
            print(f"kernels_cascade_{row['family']}_k{row['k']},"
                  f"{row['cascade_fused_fwd_us']:.2f},"
                  f"bwd_us={row['cascade_bwd_us']:.2f};"
                  f"bytes_row="
                  f"{row['roofline_bytes_per_row']['fwd_fused']}")
        for row in out["cascade_bwd"]:
            print(f"kernels_cascade_bwd_sweep_k{row['k']},"
                  f"{row['reverse_sweep_us']:.2f},"
                  f"bytes_row="
                  f"{row['roofline_bytes_per_row']['reverse_sweep']}")
            print(f"kernels_cascade_bwd_scan_k{row['k']},"
                  f"{row['per_layer_scan_us']:.2f},"
                  f"bytes_row="
                  f"{row['roofline_bytes_per_row']['per_layer_scan']}")
        for row in out["paged_attn"]:
            print(f"kernels_paged_attn_mb{row['mb']}_t{row['t']},"
                  f"{row['fused_us']:.2f},"
                  f"gather_us={row['gather_us']:.2f};"
                  f"bytes_slot={row['bytes_per_slot']['kernel']}"
                  f"(gather={row['bytes_per_slot']['gather']})")
    return out


if __name__ == "__main__":
    main()
