"""Kernel-level benchmark for the fused ACDC training hot path.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--quick]

Two views, written to ``results/BENCH_kernels.json``:

1. **Analytic roofline bytes-per-row model** (the paper's section 5
   accounting, exact on any hardware): per-row HBM traffic of

   * the fused forward           (8N: read row + write row, fp32),
   * the per-layer order-K scan  (8KN: every layer round-trips HBM),
   * the whole-cascade fused fwd (8N, INDEPENDENT of K — the tentpole),
   * the old four-matmul XLA backward (48N: gc/h2/dh1 each round-trip),
   * the fused Pallas backward   (12N: read x + read g + write dx).

   Transform-matrix traffic is excluded: C/C^T are O(N^2) one-offs
   amortized over the batch in every variant equally.

2. **Wall-clock** of the real code paths on this host (interpret mode on
   CPU — directional only, the container is not the target hardware;
   compiled kernels on TPU) for fwd, bwd (via ``jax.vjp``) and order-K
   cascades fused vs per-layer.

This seeds the repo's perf trajectory: future PRs diff this JSON.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks._util import time_us as _time
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import ops

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

FP32 = 4  # bytes; the kernels' HBM-facing activation width in this repo


def per_row_bytes(n: int, k: int = 1) -> dict:
    """Analytic per-row HBM bytes for each implementation variant."""
    return {
        "fwd_fused": 2 * FP32 * n,                 # 8N: x in, y out
        "fwd_per_layer_cascade": 2 * FP32 * n * k,  # 8KN: K round trips
        "fwd_cascade_fused": 2 * FP32 * n,          # 8N independent of K
        "bwd_four_matmul_xla": 12 * FP32 * n,       # 48N: x,g,dx + 3 inter-
                                                    # mediates x2 (wr+rd) +
                                                    # 3 reduction re-reads
        "bwd_fused": 3 * FP32 * n,                  # 12N: x, g in; dx out
    }



def bench_layer(n: int, m: int, iters: int) -> dict:
    r = jax.random.PRNGKey(n)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    g = jax.random.normal(jax.random.fold_in(r, 3), (m, n))

    fwd = jax.jit(ops.acdc_fused_nobias)

    @jax.jit
    def bwd(x, a, d, g):
        _, vjp = jax.vjp(ops.acdc_fused_nobias, x, a, d)
        return vjp(g)

    regime = "fused" if n <= fused_mod.MAX_FUSED_N else "two_call"
    return {
        "n": n, "rows": m, "regime": regime,
        "fwd_us": _time(fwd, x, a, d, iters=iters),
        "bwd_us": _time(bwd, x, a, d, g, iters=iters),
        "roofline_bytes_per_row": per_row_bytes(n),
    }


def bench_cascade(n: int, k: int, m: int, iters: int) -> dict:
    r = jax.random.PRNGKey(100 + k)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))

    fused = jax.jit(lambda x, a, d: ops.acdc_cascade_op(
        x, a, d, relu=True, permute=True))
    per_layer = jax.jit(lambda x, a, d: ops._cascade_per_layer(
        x, a, d, None, True, True))

    @jax.jit
    def bwd(x, a, d):
        return jax.grad(lambda a: jnp.sum(ops.acdc_cascade_op(
            x, a, d, relu=True, permute=True)))(a)

    rb = per_row_bytes(n, k)
    return {
        "n": n, "k": k, "rows": m,
        "cascade_fused_fwd_us": _time(fused, x, a, d, iters=iters),
        "cascade_per_layer_fwd_us": _time(per_layer, x, a, d, iters=iters),
        "cascade_fused_bwd_us": _time(bwd, x, a, d, iters=iters),
        "roofline_bytes_per_row": {
            "fused": rb["fwd_cascade_fused"],
            "per_layer": rb["fwd_per_layer_cascade"],
        },
    }


def main(csv: bool = True, argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    iters = 2 if args.quick else 5
    m = 128 if args.quick else 256

    layer_sizes = (128, 256) if args.quick else (128, 256, 512)
    cascade_ks = (1, 2, 4) if args.quick else (1, 2, 4, 8)

    out = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "layers": [bench_layer(n, m, iters) for n in layer_sizes],
        "cascades": [bench_cascade(256, k, m, iters) for k in cascade_ks],
        # The acceptance check: cascade fusion moves 8N bytes/row for
        # EVERY K, while the per-layer path scales as 8KN.
        "cascade_bytes_model": {
            str(k): per_row_bytes(256, k) for k in cascade_ks
        },
    }

    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    if csv:
        for row in out["layers"]:
            print(f"kernels_fwd_n{row['n']},{row['fwd_us']:.2f},"
                  f"regime={row['regime']}")
            print(f"kernels_bwd_n{row['n']},{row['bwd_us']:.2f},"
                  f"roofline_bytes_row="
                  f"{row['roofline_bytes_per_row']['bwd_fused']}")
        for row in out["cascades"]:
            print(f"kernels_cascade_fused_k{row['k']},"
                  f"{row['cascade_fused_fwd_us']:.2f},"
                  f"bytes_row={row['roofline_bytes_per_row']['fused']}")
            print(f"kernels_cascade_per_layer_k{row['k']},"
                  f"{row['cascade_per_layer_fwd_us']:.2f},"
                  f"bytes_row={row['roofline_bytes_per_row']['per_layer']}")
    return out


if __name__ == "__main__":
    main()
