"""Serving-path benchmark: sequential-decode prefill vs batched prefill vs
continuous batching vs the paged block KV cache.

    PYTHONPATH=src python -m benchmarks.bench_serve [--arch qwen3_1_7b]
        [--slots 4] [--prompt-len 32] [--gen 32] [--requests 12]
        [--block-size 16]

Four modes over the same smoke-scale model and workload:

* ``sequential``  — the pre-engine serving path: the prompt is fed one
  token at a time through the fused decode step (``prompt_len`` dispatches
  per request), then greedy decode;
* ``batched_prefill`` — ONE lowered prefill program per batch ingests all
  prompts, then lockstep greedy decode (static batching);
* ``continuous``  — the slot engine: per-admission prefill (one dispatch
  per request), one fused decode tick for all active slots, eviction +
  refill under a Poisson-ish ragged arrival stream;
* ``paged``       — the same engine and workload on the paged block KV
  cache, with the pool sized from the mix's actual demand (top
  ``n_slots`` per-request page needs) instead of ``n_slots * max_len``;
* ``paged_fused`` — the paged run again with the fused streaming
  paged-attention kernel forced (``kernels/paged_attn.py``) instead of
  the block-table gather, asserting token-identical streams and that the
  dispatch counters recorded only fused decisions.

An analytic ``attn_bytes_model`` section accompanies the paged rows: the
engine's per-tick ``attn_gather_bytes`` / ``attn_kernel_bytes`` counters
(model, not measurement — both advance whichever path ran), plus the same
workload re-run with a doubled page table to pin the memory-model claim:
gather traffic scales with ``max_len`` while the kernel's is a function
of live lengths only.  Wall-clock for ``paged_fused`` is reported but
tagged ``non_roofline`` off-TPU, where the kernel runs interpreted.

``--spec`` adds an A/B pair on an ACDC SELL smoke model: ``spec_baseline``
(the plain continuous engine) vs ``spec`` (truncated-cascade self-draft +
batched k-token verify), asserting token-identical greedy streams with
strictly fewer target-model dispatches per generated token, and reporting
the measured draft acceptance rate.

Accounting is comparable across modes: ``decode_tok_per_s`` is always
decode-step tokens over decode-step time (the engine modes exclude the
per-request prefill-sampled first token and the prefill dispatch time —
mixing them in made continuous look ~5x slower than sequential);
``total_s`` keeps the end-to-end view.  Emits ``results/BENCH_serve.json``
with two acceptance checks: engine modes issue ONE lowered prefill program
per admission, and the paged pool holds strictly fewer cache bytes than
the dense slabs while emitting identical greedy token streams.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import timing_meta
from repro.configs import registry
from repro.dist import steps as steps_mod
from repro.kernels import ops
from repro.kernels import paged_attn
from repro.models import get_model
from repro.obs import Observability, SpanTracer, set_global_tracer
from repro.serving import Engine, Request
from repro.serving.request import make_ragged_requests

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def bench_sequential(model, cfg, params, prompts, gen: int):
    """Old serving path: prompt tokens through the decode step one by one."""
    b, p = prompts.shape
    serve = jax.jit(steps_mod.make_serve_step(model, cfg))
    cache = model.init_cache(cfg, b, p + gen + 1)
    rng = jax.random.PRNGKey(0)
    # warmup compile outside the timed region
    serve(params, model.init_cache(cfg, b, p + gen + 1), prompts[:, 0],
          jnp.zeros((b,), jnp.int32), rng)[0].block_until_ready()

    t0 = time.perf_counter()
    tok = prompts[:, 0]
    dispatches = 0
    for i in range(p - 1):
        _, cache = serve(params, cache, tok, jnp.full((b,), i, jnp.int32),
                         rng)
        tok = prompts[:, i + 1]
        dispatches += 1
    nxt, cache = serve(params, cache, tok, jnp.full((b,), p - 1, jnp.int32),
                       rng)
    dispatches += 1
    jax.block_until_ready(nxt)
    t_first = time.perf_counter() - t0          # ttft: whole prompt + 1 tok

    t0 = time.perf_counter()
    for i in range(gen - 1):
        nxt, cache = serve(params, cache, nxt,
                           jnp.full((b,), p + i, jnp.int32), rng)
    jax.block_until_ready(nxt)
    t_dec = time.perf_counter() - t0
    return {
        "mode": "sequential",
        "prefill_dispatches_per_request": dispatches,
        "ttft_s": t_first,
        "decode_tok_per_s": b * (gen - 1) / max(t_dec, 1e-9),
        "total_s": t_first + t_dec,
        "tokens_out": b * gen,
    }


def bench_batched_prefill(model, cfg, params, prompts, gen: int):
    b, p = prompts.shape
    prefill = jax.jit(steps_mod.make_prefill_step(model, cfg))
    serve = jax.jit(steps_mod.make_serve_step(model, cfg))
    lengths = jnp.full((b,), p, jnp.int32)
    rng = jax.random.PRNGKey(0)
    # warmup compiles
    cache = model.init_cache(cfg, b, p + gen + 1)
    warm, wcache = prefill(params, cache, prompts, lengths)
    serve(params, wcache, jnp.argmax(warm, -1).astype(jnp.int32),
          lengths, rng)[0].block_until_ready()

    cache = model.init_cache(cfg, b, p + gen + 1)
    t0 = time.perf_counter()
    last, cache = prefill(params, cache, prompts, lengths)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_first = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(gen - 1):
        tok, cache = serve(params, cache, tok,
                           jnp.full((b,), p + i, jnp.int32), rng)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    return {
        "mode": "batched_prefill",
        "prefill_dispatches_per_request": 1,
        "ttft_s": t_first,
        "decode_tok_per_s": b * (gen - 1) / max(t_dec, 1e-9),
        "total_s": t_first + t_dec,
        "tokens_out": b * gen,
    }


def bench_continuous(model, cfg, params, n_slots: int, prompt_len: int,
                     gen: int, n_requests: int, paged: bool = False,
                     block_size: int = 16, n_blocks=None, spec_k: int = 0,
                     draft_depth=None, mode: str = None,
                     force_fused: bool = False, max_len: int = None):
    """Ragged Poisson-ish stream: arrivals are interleaved with ticks.

    Returns (row, requests) so the paged run can be checked token-for-token
    against the dense run and the pool can be sized from actual demand.
    ``spec_k > 0`` serves the same workload speculatively (truncated-cascade
    self-draft at ``draft_depth``).  ``force_fused`` routes paged attention
    through the fused streaming kernel regardless of backend;
    ``max_len`` overrides the per-slot ceiling (used to grow the page
    table without changing the workload, for the bytes model).
    """
    reqs = make_ragged_requests(cfg.vocab_size, n_requests, prompt_len, gen,
                                vary_budget=True)
    # exponential inter-arrival gaps measured in ticks
    rs = np.random.RandomState(1)
    gaps = rs.exponential(scale=max(gen / (2 * n_slots), 0.5),
                          size=n_requests)
    arrive_at = np.floor(np.cumsum(gaps)).astype(int)

    was_forced = paged_attn.FORCE_FUSED
    paged_attn.FORCE_FUSED = force_fused or was_forced
    dispatches_before = dict(ops.PAGED_ATTN_DISPATCHES)
    try:
        eng = Engine(model, cfg, params, n_slots=n_slots,
                     max_len=max_len or (prompt_len + gen + 1),
                     max_prompt_len=prompt_len,
                     paged=paged, block_size=block_size, n_blocks=n_blocks,
                     spec_k=spec_k, draft_depth=draft_depth)
        # warmup both compiled programs on a throwaway request, then
        # snapshot the stats so the report covers only the timed workload
        warm = Request(rid=10**6, prompt=[1, 2, 3], max_new_tokens=2)
        eng.run([warm], max_ticks=50)
        warm_stats = dict(eng.stats)

        t0 = time.perf_counter()
        nxt = 0
        tick = 0
        limit = n_requests * (prompt_len + gen) + 64
        while nxt < n_requests or eng.has_work:
            while nxt < n_requests and arrive_at[nxt] <= tick:
                eng.submit(reqs[nxt])
                nxt += 1
            eng.tick()
            tick += 1
            if tick > limit:
                raise RuntimeError("engine not drained")
        dt = time.perf_counter() - t0
    finally:
        paged_attn.FORCE_FUSED = was_forced
    toks = sum(len(r.generated) for r in reqs)
    # the first token of every request is sampled from the prefill logits;
    # only the rest are decode-step output, and only decode-step time pays
    # for them — same basis as the sequential/batched rows
    decode_toks = toks - n_requests
    decode_s = eng.stats["decode_s"] - warm_stats["decode_s"]
    ttft = [r.t_first_token - r.t_submit for r in reqs]
    if mode is None:
        mode = "spec" if spec_k else ("paged" if paged else "continuous")
    row = {
        "mode": mode,
        "prefill_dispatches_per_request": 1,
        "prefill_dispatches_total": eng.stats["prefill_dispatches"]
        - warm_stats["prefill_dispatches"],
        "decode_ticks": eng.stats["decode_ticks"]
        - warm_stats["decode_ticks"],
        "ttft_s": float(np.median(ttft)),
        "ttft_max_s": float(np.max(ttft)),
        "decode_tok_per_s": decode_toks / max(decode_s, 1e-9),
        "decode_s": decode_s,
        "prefill_s": eng.stats["prefill_s"] - warm_stats["prefill_s"],
        "total_s": dt,
        "tokens_out": toks,
        "n_requests": n_requests,
        "cache_bytes": eng.cache_bytes,
    }
    if paged:
        ticks = max(row["decode_ticks"], 1)
        gather_b = (eng.stats["attn_gather_bytes"]
                    - warm_stats["attn_gather_bytes"])
        kernel_b = (eng.stats["attn_kernel_bytes"]
                    - warm_stats["attn_kernel_bytes"])
        row.update({
            "block_size": eng.block_size,
            "pool_blocks": eng.allocator.n_blocks,
            "dense_parity_blocks": n_slots * eng.max_blocks,
            "max_blocks_per_slot": eng.max_blocks,
            "peak_blocks_in_use": eng.allocator.peak_in_use,
            "stalled_slot_ticks": eng.stats["stalled_slot_ticks"]
            - warm_stats["stalled_slot_ticks"],
            "preempted": eng.stats["preempted"] - warm_stats["preempted"],
            "attn_gather_bytes": gather_b,
            "attn_kernel_bytes": kernel_b,
            "attn_gather_bytes_per_tick": gather_b / ticks,
            "attn_kernel_bytes_per_tick": kernel_b / ticks,
            "attn_dispatches": {
                k: ops.PAGED_ATTN_DISPATCHES[k] - dispatches_before[k]
                for k in dispatches_before},
        })
    if spec_k:
        drafted = eng.stats["drafted"] - warm_stats["drafted"]
        accepted = eng.stats["accepted"] - warm_stats["accepted"]
        row.update({
            "spec_k": spec_k,
            "draft_depth": eng.draft.depth,
            "drafted": drafted,
            "accepted": accepted,
            "acceptance_rate": accepted / max(drafted, 1),
            # target-model dispatches per generated decode token: the
            # speculative win — one verify advances a slot several tokens
        })
    row["target_dispatches_per_token"] = (row["decode_ticks"]
                                          / max(decode_toks, 1))
    return row, reqs


def pool_blocks_for_mix(reqs, n_slots: int, prompt_len: int, gen: int,
                        block_size: int) -> int:
    """Size the paged pool from the workload mix: the sum of the top
    ``n_slots`` per-request page demands bounds what any concurrent slot
    set can hold, so this pool can never deadlock — yet it is far below
    dense parity whenever the mix is ragged (the whole point of paging).
    """
    max_len = prompt_len + gen + 1
    demands = sorted(
        (-(-min(r.prompt_len + r.max_new_tokens + 1, max_len) // block_size)
         for r in reqs),
        reverse=True)
    return sum(demands[:n_slots])


def bench_overload(args):
    """Overload mode (``--overload``): the page pool is sized to ~60% of
    the workload mix's demand, arrivals come in faster than the engine
    drains (jittered Poisson gaps), and half the stream carries deadlines
    across three priority bands — so the resilience machinery, not the
    steady-state path, carries the run: admissions gate, slots stall,
    deadlocks break by preempt-and-requeue, queued SLOs time out, and the
    degradation ladder may bound the queue.

    Reports p50/p99 TTFT and TPOT over requests that got a first token
    plus the preempt / requeue / timeout / shed counters, and asserts the
    overload guarantees: every request reaches a terminal state, NO
    request is killed with ``cache_full`` (the seed's behaviour when the
    pool deadlocked — requeue-with-recompute replaces it), and the page
    pool comes back leak-free.

    The latency percentiles are read from the engine's shared obs
    histograms (``serve_ttft_seconds`` / ``serve_tpot_seconds``) and
    cross-checked against the raw per-request lists to within one
    histogram bin width — the log-bin accuracy contract in
    ``repro/obs/metrics.py``.  The run is span-traced; the Chrome trace
    lands in ``results/TRACE_serve_overload.json``.  ``--spec`` serves
    the overload stream speculatively (ACDC SELL smoke model,
    truncated-cascade self-draft) so the trace also covers the
    draft/verify path.
    """
    cfg = registry.get_smoke_config(args.arch)
    if args.spec:
        # speculation needs cascades to truncate (see bench_spec)
        cfg = dataclasses.replace(cfg, sell_kind="acdc", sell_k=4,
                                  sell_permute=False, sell_init_std=0.02)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    n, gen = args.requests, args.gen
    reqs = make_ragged_requests(
        cfg.vocab_size, n, args.prompt_len, gen, seed=2, vary_budget=True,
        deadline_range=(2.0, 10.0), deadline_frac=0.5, n_priorities=3)
    demand = pool_blocks_for_mix(reqs, args.slots, args.prompt_len, gen,
                                 args.block_size)
    # max_prompt_len covers prompt + full generation so ANY active request
    # can re-prefill after preemption: under overload the engine must
    # always be able to trade latency instead of killing streams
    max_prompt = args.prompt_len + gen
    min_pool = -(-(max_prompt + 1) // args.block_size)
    pool = max(min_pool, int(0.6 * demand))
    tracer = SpanTracer()
    set_global_tracer(tracer)       # allocator audits ride along
    obs = Observability(tracer=tracer)
    eng = Engine(model, cfg, params, n_slots=args.slots,
                 max_len=max_prompt + 1, max_prompt_len=max_prompt,
                 paged=True, block_size=args.block_size, n_blocks=pool,
                 spec_k=args.spec_k if args.spec else 0, obs=obs)
    warm = Request(rid=10**6, prompt=[1, 2, 3], max_new_tokens=2)
    eng.run([warm], max_ticks=50)
    # exclude the compile-warmup request from the reported percentiles
    h_ttft = obs.registry.get("serve_ttft_seconds")
    h_tpot = obs.registry.get("serve_tpot_seconds")
    h_ttft.reset()
    h_tpot.reset()

    # arrivals ~2x faster than the continuous bench: sustained overload
    rs = np.random.RandomState(4)
    gaps = rs.exponential(scale=max(gen / (4 * args.slots), 0.25), size=n)
    arrive_at = np.floor(np.cumsum(gaps)).astype(int)
    t0 = time.perf_counter()
    nxt = 0
    tick = 0
    limit = 4 * n * (args.prompt_len + gen) + 256
    while nxt < n or eng.has_work:
        while nxt < n and arrive_at[nxt] <= tick:
            eng.submit(reqs[nxt])
            nxt += 1
        eng.tick()
        tick += 1
        if tick > limit:
            raise RuntimeError("overload run not drained")
    dt = time.perf_counter() - t0

    assert all(r.done for r in reqs), "request left non-terminal"
    reasons = {}
    for r in reqs:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    assert reasons.get("cache_full", 0) == 0, (
        "overload killed a stream with cache_full — preempt-requeue "
        "should have recomputed it")
    eng.allocator.audit()
    assert eng.allocator.n_free == eng.allocator.n_blocks

    # latency percentiles come from the SHARED obs histograms; the raw
    # per-request lists only cross-check them (within one bin width, the
    # histogram's documented accuracy)
    served = [r.t_first_token - r.t_submit for r in reqs
              if r.t_first_token is not None]
    tpot = [(r.t_finish - r.t_first_token) / (len(r.generated) - 1)
            for r in reqs if r.t_first_token is not None
            and r.t_finish is not None and len(r.generated) > 1]
    for h, raw in ((h_ttft, served), (h_tpot, tpot)):
        assert h.count == len(raw), (
            f"{h.name}: {h.count} observations vs {len(raw)} requests")
        for q in (50.0, 99.0):
            hp = h.percentile(q)
            lp = float(np.percentile(raw, q)) if raw else None
            if hp is None or lp is None:
                assert hp is None and lp is None
                continue
            tol = max(h.bin_width(hp), h.bin_width(lp))
            assert abs(hp - lp) <= tol, (
                f"{h.name} p{q:.0f}: histogram {hp:.4f} vs list {lp:.4f} "
                f"exceeds one bin width ({tol:.4f})")

    os.makedirs(RESULTS, exist_ok=True)
    trace_path = os.path.join(RESULTS, "TRACE_serve_overload.json")
    tracer.write(trace_path)
    set_global_tracer(None)
    names = {e["name"] for e in tracer.chrome_trace()["traceEvents"]}
    assert {"queued", "prefill", "decode"} <= names, (
        f"trace missing lifecycle spans: {sorted(names)}")
    if eng.stats["preempted"]:
        assert {"preempt", "backoff"} <= names
    if eng.stats["degrade_down"]:
        assert "ladder" in names
    for r in reqs:
        assert len(tracer.terminals_for(r.rid)) == 1, (
            f"rid={r.rid}: expected exactly one terminal event")

    row = {
        "mode": "overload",
        "n_requests": n,
        "pool_blocks": pool,
        "pool_vs_demand": pool / max(demand, 1),
        "finish_reasons": reasons,
        "ttft_p50_s": h_ttft.percentile(50),
        "ttft_p99_s": h_ttft.percentile(99),
        "ttft_p50_list_s": (float(np.percentile(served, 50))
                            if served else None),
        "ttft_p99_list_s": (float(np.percentile(served, 99))
                            if served else None),
        "tpot_p50_s": h_tpot.percentile(50),
        "tpot_p99_s": h_tpot.percentile(99),
        "trace_out": os.path.relpath(trace_path),
        "preempted": eng.stats["preempted"],
        "requeued": eng.stats["requeued"],
        "deadline_preempts": eng.stats["deadline_preempts"],
        "timeout": eng.stats["timeout"],
        "rejected": eng.stats["rejected"],
        "stalled_slot_ticks": eng.stats["stalled_slot_ticks"],
        "degrade_down": eng.stats["degrade_down"],
        "degrade_up": eng.stats["degrade_up"],
        "tokens_out": sum(len(r.generated) for r in reqs),
        "total_s": dt,
    }
    if args.spec:
        row.update({
            "spec_k": args.spec_k,
            "drafted": eng.stats["drafted"],
            "accepted": eng.stats["accepted"],
            "acceptance_rate": eng.stats["acceptance_rate"],
        })
    return row


def bench_spec(args):
    """Speculative vs non-speculative on an ACDC SELL smoke model.

    The truncated-cascade draft needs cascades to truncate, so this runs
    on the smoke config with ``sell_kind='acdc'`` (K = 4, un-riffled, a
    near-converged ``sell_init_std`` so the truncated tail approximates
    the target the way a trained cascade does — see spec/draft.py on why
    riffled cascades truncate poorly).  The greedy spec stream must be
    token-identical to the baseline while spending strictly fewer target
    dispatches per generated token.
    """
    cfg = dataclasses.replace(
        registry.get_smoke_config(args.arch), sell_kind="acdc", sell_k=4,
        sell_permute=False, sell_init_std=0.02)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    base, base_reqs = bench_continuous(
        model, cfg, params, args.slots, args.prompt_len, args.gen,
        args.requests, mode="spec_baseline")
    spec, spec_reqs = bench_continuous(
        model, cfg, params, args.slots, args.prompt_len, args.gen,
        args.requests, spec_k=args.spec_k, draft_depth=2)
    for b, s in zip(base_reqs, spec_reqs):
        assert s.generated == b.generated, (
            f"rid={b.rid}: spec stream diverged from baseline")
    assert (spec["target_dispatches_per_token"]
            < base["target_dispatches_per_token"]), (
        "speculation did not reduce target dispatches per token")
    return [base, spec]


def main(csv: bool = True, argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b", choices=registry.ARCHS)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=12)
    # 8-token pages: at smoke scale the coarser 16-token granularity plus
    # the trash page can round a ragged mix back above the dense footprint
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--spec", action="store_true",
                    help="also A/B speculative decoding (truncated-cascade "
                         "draft) against the continuous baseline on an "
                         "ACDC SELL smoke model")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--overload", action="store_true",
                    help="run ONLY the overload-resilience benchmark: pool "
                         "below the mix's demand, jittered Poisson "
                         "arrivals, deadlines + priorities; reports "
                         "p50/p99 TTFT and preempt/requeue/timeout/shed "
                         "counts and asserts zero cache_full kills")
    args = ap.parse_args(argv)

    if args.overload:
        row = bench_overload(args)
        os.makedirs(RESULTS, exist_ok=True)
        path = os.path.join(RESULTS, "BENCH_serve_overload.json")
        out = {"backend": jax.default_backend(),
               "timing": timing_meta(1, 1),
               "arch": args.arch, "slots": args.slots,
               "prompt_len": args.prompt_len, "gen": args.gen,
               "block_size": args.block_size, "overload": row}
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        if csv:
            fr = ";".join(f"{k}:{v}" for k, v in
                          sorted(row["finish_reasons"].items()))
            print(f"serve_overload,{row['total_s'] * 1e6:.0f},"
                  f"ttft_p50_s={row['ttft_p50_s']:.3f};"
                  f"ttft_p99_s={row['ttft_p99_s']:.3f};"
                  f"tpot_p50_s={row['tpot_p50_s']:.4f};"
                  f"tpot_p99_s={row['tpot_p99_s']:.4f};"
                  f"requeued={row['requeued']};timeout={row['timeout']};"
                  f"rejected={row['rejected']};reasons={fr}")
            print(f"wrote {os.path.relpath(path)}")
            print(f"wrote {row['trace_out']}")
        return out

    cfg = registry.get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.slots, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32)

    cont, cont_reqs = bench_continuous(
        model, cfg, params, args.slots, args.prompt_len, args.gen,
        args.requests)
    pool = pool_blocks_for_mix(cont_reqs, args.slots, args.prompt_len,
                               args.gen, args.block_size)
    paged, paged_reqs = bench_continuous(
        model, cfg, params, args.slots, args.prompt_len, args.gen,
        args.requests, paged=True, block_size=args.block_size,
        n_blocks=pool)
    fused, fused_reqs = bench_continuous(
        model, cfg, params, args.slots, args.prompt_len, args.gen,
        args.requests, paged=True, block_size=args.block_size,
        n_blocks=pool, force_fused=True, mode="paged_fused")
    # same workload, page table doubled: only the gather's analytic
    # traffic may move (the byte counters are path-independent, so the
    # cheap gather route is fine here)
    virtual = paged["max_blocks_per_slot"] * args.block_size
    paged2x, paged2x_reqs = bench_continuous(
        model, cfg, params, args.slots, args.prompt_len, args.gen,
        args.requests, paged=True, block_size=args.block_size,
        n_blocks=pool, max_len=2 * virtual, mode="paged_2x_table")
    rows = [
        bench_sequential(model, cfg, params, prompts, args.gen),
        bench_batched_prefill(model, cfg, params, prompts, args.gen),
        cont,
        paged,
        fused,
    ]
    if args.spec:
        rows += bench_spec(args)
    seq, bat = rows[0], rows[1]
    assert bat["prefill_dispatches_per_request"] == 1
    assert seq["prefill_dispatches_per_request"] == args.prompt_len
    # paged acceptance: same tokens out of a strictly smaller cache
    assert paged["preempted"] == 0
    assert paged["cache_bytes"] < cont["cache_bytes"], (
        f"paged pool {paged['cache_bytes']}B not below dense "
        f"{cont['cache_bytes']}B")
    for d, p in zip(cont_reqs, paged_reqs):
        assert p.generated == d.generated, (
            f"rid={d.rid}: paged stream diverged from dense")
    # fused-kernel acceptance: token-identical to the gather run, only
    # fused dispatches recorded, and the analytic attention traffic of
    # the streaming kernel strictly below the gather's — and unchanged
    # when the page table doubles, while the gather's doubles with it
    for g, f in zip(paged_reqs, fused_reqs):
        assert f.generated == g.generated, (
            f"rid={g.rid}: paged_fused stream diverged from paged")
    assert fused["attn_dispatches"]["fused"] > 0
    assert fused["attn_dispatches"]["gather"] == 0, (
        "paged_fused run fell back to the gather path")
    assert 0 < paged["attn_kernel_bytes"] < paged["attn_gather_bytes"]
    for g, p2 in zip(paged_reqs, paged2x_reqs):
        assert p2.generated == g.generated
    assert paged2x["attn_kernel_bytes"] == paged["attn_kernel_bytes"], (
        "kernel bytes moved with the page-table length")
    assert (paged2x["attn_gather_bytes"]
            == 2 * paged["attn_gather_bytes"]), (
        "gather bytes did not scale with the page-table length")

    out = {
        "backend": jax.default_backend(),
        # off-TPU the fused kernel runs interpreted: wall-clock rows are
        # dispatch/fusion structure, not kernel roofline numbers
        "non_roofline": jax.default_backend() != "tpu",
        "timing": timing_meta(1, 1),
        "arch": cfg.name,
        "slots": args.slots,
        "prompt_len": args.prompt_len,
        "gen": args.gen,
        "block_size": args.block_size,
        "modes": rows,
        "ttft_speedup_batched_vs_sequential":
            seq["ttft_s"] / max(bat["ttft_s"], 1e-9),
        "paged_cache_bytes_vs_dense":
            paged["cache_bytes"] / max(cont["cache_bytes"], 1),
        "attn_bytes_model": {
            "mb_pages_per_slot": paged["max_blocks_per_slot"],
            "gather_bytes_per_tick": paged["attn_gather_bytes_per_tick"],
            "kernel_bytes_per_tick": paged["attn_kernel_bytes_per_tick"],
            "kernel_vs_gather":
                paged["attn_kernel_bytes"] / paged["attn_gather_bytes"],
            "gather_bytes_at_2x_table": paged2x["attn_gather_bytes"],
            "kernel_bytes_at_2x_table": paged2x["attn_kernel_bytes"],
            "kernel_mb_independent":
                paged2x["attn_kernel_bytes"] == paged["attn_kernel_bytes"],
        },
    }
    if args.spec:
        sbase, srow = rows[-2], rows[-1]
        out["spec_acceptance_rate"] = srow["acceptance_rate"]
        out["spec_dispatches_per_token_vs_baseline"] = (
            srow["target_dispatches_per_token"]
            / max(sbase["target_dispatches_per_token"], 1e-9))
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    if csv:
        for r in rows:
            extra = ""
            if r["mode"] == "paged":
                extra = (f";cache_bytes={r['cache_bytes']}"
                         f"(dense={cont['cache_bytes']})"
                         f";peak_blocks={r['peak_blocks_in_use']}"
                         f"/{r['pool_blocks']}")
            if r["mode"] == "paged_fused":
                extra = (f";attn_bytes_per_tick="
                         f"{r['attn_kernel_bytes_per_tick']:.0f}"
                         f"(gather={r['attn_gather_bytes_per_tick']:.0f})"
                         f";dispatches=fused:{r['attn_dispatches']['fused']}"
                         f"/gather:{r['attn_dispatches']['gather']}")
            if r["mode"] == "spec":
                extra = (f";acceptance={r['acceptance_rate']:.3f}"
                         f";dispatches_per_tok="
                         f"{r['target_dispatches_per_token']:.3f}")
            print(f"serve_{r['mode']},{r['total_s'] * 1e6:.0f},"
                  f"tok_per_s={r['decode_tok_per_s']:.1f};"
                  f"ttft_s={r['ttft_s']:.3f};"
                  f"prefill_dispatches={r['prefill_dispatches_per_request']}"
                  + extra)
        print(f"wrote {os.path.relpath(path)}")
    return out


if __name__ == "__main__":
    main()
