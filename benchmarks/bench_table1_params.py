"""Paper Table 1: parameter-count reductions from SELL replacement.

Reproduces the CaffeNet bookkeeping analytically (the ImageNet training run
is out of scope offline; the *counting* is exact) and extends the table to
the assigned LM zoo — dense vs ACDC projections, per architecture.

CSV: name,us_per_call,derived   (us_per_call column carries param counts)
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.core.sell import SellConfig
from repro.models import get_model


def caffenet_rows():
    """Paper's CaffeNet: fc6 (9216->4096), fc7 (4096->4096) replaced by 12
    stacked ACDC layers at N=4608 with bias-on-D => 165,888 params."""
    rows = []
    fc6 = 9216 * 4096 + 4096
    fc7 = 4096 * 4096 + 4096
    dense_fc = fc6 + fc7
    acdc = SellConfig(kind="acdc", n_in=4608, n_out=4608, k=12,
                      bias=True).param_count()
    rows.append(("table1_caffenet_fc_dense", dense_fc, "fc6+fc7"))
    rows.append(("table1_caffenet_acdc12", acdc,
                 f"paper_claims=165888 match={acdc == 165888}"))
    # whole-model view (conv+fc8 unchanged, approx 6.45M)
    rest = 58.7e6 - dense_fc
    rows.append(("table1_caffenet_reduction",
                 (rest + dense_fc) / (rest + acdc),
                 "x-fold vs paper x6.0 (order-of-magnitude bookkeeping)"))
    return rows


def zoo_rows():
    rows = []
    for arch in registry.ARCHS:
        cfg_d = registry.get_smoke_config(arch)
        cfg_a = dataclasses.replace(cfg_d, sell_kind="acdc", sell_k=2)
        pd = get_model(cfg_d).init(jax.random.PRNGKey(0), cfg_d)
        pa = get_model(cfg_a).init(jax.random.PRNGKey(0), cfg_a)
        nd = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pd))
        na = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pa))
        rows.append((f"table1_{arch}_dense_params", nd, "smoke config"))
        rows.append((f"table1_{arch}_acdc_params", na,
                     f"reduction={nd / na:.2f}x"))
    return rows


def full_config_projection_rows():
    """Analytic projection-parameter counts at FULL config scale."""
    rows = []
    for arch in ("deepseek_67b", "llava_next_34b", "qwen3_1_7b"):
        cfg = registry.get_config(arch)
        d = cfg.d_model
        h = cfg.n_heads * cfg.head_dim_
        dense = h * d + 3 * d * cfg.d_ff          # attn_out + gated mlp
        acdc_out = SellConfig(kind="acdc", n_in=h, n_out=d, k=2, bias=False,
                              lane_multiple=128).param_count()
        acdc_mlp = 3 * SellConfig(kind="acdc", n_in=d, n_out=cfg.d_ff, k=2,
                                  bias=False, lane_multiple=128).param_count()
        rows.append((f"table1_full_{arch}_proj_dense_per_layer", dense, ""))
        rows.append((f"table1_full_{arch}_proj_acdc_per_layer",
                     acdc_out + acdc_mlp,
                     f"reduction={dense / (acdc_out + acdc_mlp):.0f}x"))
    return rows


def main(csv=True):
    rows = caffenet_rows() + zoo_rows() + full_config_projection_rows()
    if csv:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    main()
