import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (EXPERIMENTS.md section Perf).

Runs the three selected cells through a sequence of hypothesis-driven
changes (each a ModelConfig override implemented as a first-class feature,
equivalence-tested in tests/test_perf_impls.py), re-lowers, re-derives the
roofline terms, and records hypothesis -> before -> after per step.

    PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]
"""

import argparse
import json
import time

from benchmarks import roofline

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "hillclimb")

# (cell, selection reason, iterations: [(tag, overrides, hypothesis)])
PLANS = [
    {
        "arch": "moonshot_v1_16b_a3b", "shape": "train_4k",
        "why": "worst roofline fraction / useful ratio ~0.003: the one-hot "
               "MoE dispatch einsums are O(T*E*C*d), quadratic in tokens",
        "iters": [
            ("moe_scatter", {"moe_impl": "scatter"},
             "scatter/gather dispatch is O(T*k*d); expect ~100x less "
             "dispatch compute and the (T,E,C) temporaries gone"),
            ("moe_scatter+ce_onehot",
             {"moe_impl": "scatter", "ce_impl": "onehot"},
             "CE gather all-gathers vocab-sharded logits; lse+onehot "
             "reduces locally -> collective bytes drop by ~tokens*V/shard"),
            ("all_on",
             {"moe_impl": "scatter", "ce_impl": "onehot",
              "attn_impl": "chunked"},
             "chunked attention removes the (S,S) score materialization "
             "-> memory term drops"),
        ],
    },
    {
        "arch": "seamless_m4t_large_v2", "shape": "train_4k",
        "why": "most collective-bound cell (vocab 256206 not divisible by "
               "the 16-way model axis -> logits replicated + gathered)",
        "iters": [
            ("ce_onehot", {"ce_impl": "onehot"},
             "onehot CE avoids gathering (tokens, V) logits; collective "
             "term should fall by the logits traffic"),
            ("ce_onehot+chunked",
             {"ce_impl": "onehot", "attn_impl": "chunked"},
             "enc self-attn + cross-attn + dec self-attn all materialize "
             "score matrices; chunking cuts the memory term"),
        ],
    },
    {
        "arch": "qwen3_1_7b", "shape": "train_4k",
        "why": "most representative of the paper's technique: ACDC "
               "projections (sell=acdc) vs dense, then optimized",
        "sell": "acdc",
        "iters": [
            ("acdc_baseline", {},
             "paper-faithful ACDC projections: O(N) params; compute term "
             "should DROP vs dense (fewer projection FLOPs) while "
             "collective term stays (FSDP gathers mostly gone: diagonals "
             "are tiny)"),
            ("acdc+ce_onehot", {"ce_impl": "onehot"},
             "vocab gather dominates after projections shrink"),
            ("acdc+ce+chunked",
             {"ce_impl": "onehot", "attn_impl": "chunked"},
             "attention scores become the residual memory term"),
            ("acdc_fft",
             {"ce_impl": "onehot", "attn_impl": "chunked",
              "sell_method": "fft"},
             "DCT-via-FFT lowers O(N^2) matmul-DCT to O(N log N): compute "
             "term down further (TPU caveat: butterflies are VPU-bound, "
             "so wall-clock may prefer the MXU matmul below N~4k)"),
        ],
    },
]


def run_plan(plan):
    os.makedirs(RESULTS, exist_ok=True)
    arch, shape = plan["arch"], plan["shape"]
    sell = plan.get("sell", "dense")
    out = {"arch": arch, "shape": shape, "why": plan["why"], "steps": []}

    base = roofline.analyze_cell(arch, shape, sell="dense", tag="hc_base")
    print(f"[base ] {arch}.{shape} cmp={base['compute_s']:.3e} "
          f"mem={base['memory_s']:.3e} col={base['collective_s']:.3e} "
          f"dominant={base['dominant']}", flush=True)
    out["baseline"] = base
    prev = base
    for tag, overrides, hypothesis in plan["iters"]:
        t0 = time.time()
        rec = roofline.analyze_cell(arch, shape, sell=sell,
                                    cfg_overrides=overrides, tag=tag)
        dom = prev["dominant"]
        delta = (prev[dom] - rec[dom]) / max(prev[dom], 1e-12)
        confirmed = rec[prev["dominant"]] < prev[prev["dominant"]]
        print(f"[{tag:22s}] cmp={rec['compute_s']:.3e} "
              f"mem={rec['memory_s']:.3e} col={rec['collective_s']:.3e} "
              f"dom={rec['dominant']} d({dom})={delta:+.1%} "
              f"{'CONFIRMED' if confirmed else 'REFUTED'} "
              f"({time.time()-t0:.0f}s)", flush=True)
        out["steps"].append({
            "tag": tag, "overrides": overrides, "hypothesis": hypothesis,
            "before": {k: prev[k] for k in
                       ("compute_s", "memory_s", "collective_s", "dominant")},
            "after": {k: rec[k] for k in
                      ("compute_s", "memory_s", "collective_s", "dominant",
                       "useful_flops_ratio", "roofline_fraction")},
            "dominant_delta": delta,
            "confirmed": bool(confirmed),
        })
        prev = rec
    with open(os.path.join(RESULTS, f"{arch}.{shape}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=None,
                    help="index into PLANS; default all")
    args = ap.parse_args()
    plans = PLANS if args.cell is None else [PLANS[args.cell]]
    for plan in plans:
        run_plan(plan)


if __name__ == "__main__":
    main()
