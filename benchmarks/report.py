"""Render EXPERIMENTS.md tables from results/ JSON (dry-run + roofline).

    PYTHONPATH=src python -m benchmarks.report dryrun
    PYTHONPATH=src python -m benchmarks.report roofline
"""

import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..", "results")


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(ROOT, "dryrun", "*.json"))):
        d = json.load(open(f))
        if d["status"] == "ok":
            mem = d["memory"]
            coll = d["collectives"]
            kinds = ",".join(f"{k.split('-')[-1][:4]}:{v}"
                             for k, v in coll["count"].items() if v)
            rows.append((d["cell"], d["n_devices"],
                         f"{mem.get('argument_size_in_bytes', 0)/2**30:.2f}",
                         f"{mem.get('temp_size_in_bytes', 0)/2**30:.1f}",
                         kinds, f"{d['compile_s']:.0f}s"))
        elif d["status"] == "skipped":
            rows.append((d["cell"], "—", "—", "—", "skip (sub-quadratic "
                         "contract, DESIGN.md §4)", "—"))
    out = ["| cell | devices | args GiB/dev | temp GiB/dev | collectives "
           "(count, loop body printed once) | compile |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def roofline_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(ROOT, "roofline", "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            rows.append(r)
    out = ["| cell | compute s | memory s (HLO ub) | collective s | dominant "
           "| useful/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        sell = "" if r.get("sell", "dense") == "dense" else f" [{r['sell']}]"
        out.append(
            f"| {r['cell']}{sell} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r['dominant'].replace('_s', '')} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.2%} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    print({"dryrun": dryrun_table, "roofline": roofline_table}[which]())
