import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape) on the single-pod 16x16 mesh:

    compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s
    memory_s     = HLO_bytes_per_device / 819 GB/s
    collective_s = collective_bytes_per_device / 50 GB/s

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count, and the compiled HLO text prints each loop body once, so a naive
read undercounts scanned-layer models by ~L x.  We correct with a
two-point extrapolation taken from the compiled artifacts themselves:
compile the model at L=l1 and L=2*l1 layers; anything linear in depth
(layer flops, layer bytes, per-layer FSDP all-gathers) extrapolates as

    metric(L) = metric(l1) + (L - l1)/l1 * (metric(2*l1) - metric(l1))

which also isolates the depth-independent part (embedding, loss, final
collectives).  MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*D
(inference) with N = active params (MoE-aware).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--cell arch.shape] [--all]
    PYTHONPATH=src python -m benchmarks.roofline --table   # markdown table
"""

import argparse
import dataclasses
import json
import time

import numpy as np

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "roofline")
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


# ---------------------------------------------------------------------------
# Analytic model FLOPs (MoE-aware).
# ---------------------------------------------------------------------------

def count_params(arch: str, active: bool = False) -> int:
    import functools
    import jax
    from repro.configs import registry
    from repro.models import get_model

    cfg = registry.get_config(arch)
    model = get_model(cfg)
    abs_p = jax.eval_shape(functools.partial(model.init, cfg=cfg),
                           jax.random.PRNGKey(0))
    from repro.optim.optimizers import tree_paths
    paths = tree_paths(abs_p)
    total = 0
    for path, leaf in zip(jax.tree.leaves(paths), jax.tree.leaves(abs_p)):
        n = int(np.prod(leaf.shape))
        if active and "experts/" in path and cfg.n_experts:
            n = int(n * cfg.top_k / cfg.n_experts)
        total += n
    return total


def model_flops(arch: str, shape_name: str) -> float:
    """Global analytic FLOPs for one step of this cell."""
    from repro.configs import registry
    shape = registry.get_shape(shape_name)
    n_active = count_params(arch, active=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# Two-point compiled extrapolation.
# ---------------------------------------------------------------------------

def _compile_metrics(arch, shape_name, n_layers, sell="dense",
                     cfg_overrides=None):
    import jax
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    # scan_unroll=True: XLA cost_analysis counts while bodies ONCE, so the
    # small-L compiles must be unrolled for per-layer costs to be visible.
    overrides = {"scan_unroll": True, **(cfg_overrides or {})}
    fn, args, in_sh, out_sh = dryrun.build_cell(
        arch, shape_name, mesh, sell=sell, n_layers=n_layers,
        cfg_overrides=overrides)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
    text = compiled.as_text()
    coll = dryrun.collective_bytes(text)
    # NOTE: compiled.cost_analysis() on the CPU backend omits dots inside
    # fused/called computations — flops/bytes are parsed from the optimized
    # HLO text instead (dryrun.hlo_text_analysis).
    hlo = dryrun.hlo_text_analysis(text)
    return {
        "flops": float(hlo["flops"]),
        "bytes": float(hlo["bytes"]),
        "coll": float(coll["total_bytes"]),
        "coll_by_kind": coll["bytes"],
        "counts": coll["count"],
        "mem_args": int(compiled.memory_analysis().argument_size_in_bytes),
        "mem_temp": int(compiled.memory_analysis().temp_size_in_bytes),
    }


def extrapolated_metrics(arch: str, shape_name: str, sell="dense",
                         cfg_overrides=None) -> dict:
    from repro.configs import registry
    cfg = registry.get_config(arch)
    # l1=2 (not 1): single-layer compiles can take degenerate SPMD
    # strategies (observed on llava: L=1 flops > L=2 flops); hybrids need
    # a multiple of attn_every so every group is complete.
    l1 = cfg.attn_every if cfg.family == "hybrid" else 2
    l2 = 2 * l1
    L = cfg.n_layers
    m1 = _compile_metrics(arch, shape_name, l1, sell, cfg_overrides)
    m2 = _compile_metrics(arch, shape_name, l2, sell, cfg_overrides)
    out = {}
    for k in ("flops", "bytes", "coll"):
        # clamp: tiny decode programs can show negative slope from fusion
        # noise between the two compiles
        slope = max((m2[k] - m1[k]) / l1, 0.0)
        out[k] = m1[k] + slope * (L - l1)
        out[k + "_per_layer"] = slope
        out[k + "_const"] = m1[k] - slope * l1
    out["coll_by_kind_l2"] = m2["coll_by_kind"]
    out["counts_l2"] = m2["counts"]
    return out


def analyze_cell(arch: str, shape_name: str, sell="dense",
                 cfg_overrides=None, tag="") -> dict:
    from repro.configs import registry
    if registry.skips(arch, shape_name):
        return {"cell": f"{arch}.{shape_name}", "status": "skipped"}
    t0 = time.time()
    m = extrapolated_metrics(arch, shape_name, sell, cfg_overrides)
    mf_global = model_flops(arch, shape_name)
    n_chips = 256
    compute_s = m["flops"] / PEAK_FLOPS
    memory_s = m["bytes"] / HBM_BW
    coll_s = m["coll"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    rec = {
        "cell": f"{arch}.{shape_name}" + (f".{tag}" if tag else ""),
        "status": "ok",
        "sell": sell,
        "mesh": "pod16x16",
        "hlo_flops_per_device": m["flops"],
        "hlo_bytes_per_device": m["bytes"],
        "collective_bytes_per_device": m["coll"],
        "collective_kinds": m["coll_by_kind_l2"],
        **terms,
        "dominant": dominant,
        "model_flops_global": mf_global,
        "model_flops_per_device": mf_global / n_chips,
        "useful_flops_ratio": (mf_global / n_chips) / max(m["flops"], 1.0),
        "roofline_fraction": (mf_global / n_chips / PEAK_FLOPS) / bound_s
            if bound_s > 0 else 0.0,
        "analyze_s": round(time.time() - t0, 1),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = rec["cell"] + ("" if sell == "dense" else f".{sell}")
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def render_table() -> str:
    rows = []
    for fname in sorted(os.listdir(RESULTS_DIR)):
        with open(os.path.join(RESULTS_DIR, fname)) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        rows.append(r)
    lines = [
        "| cell | compute s | memory s | collective s | dominant | "
        "useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['cell']}{'.' + r['sell'] if r['sell'] != 'dense' else ''} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2%} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, help="arch.shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sell", default="dense")
    ap.add_argument("--table", action="store_true")
    args = ap.parse_args()
    if args.table:
        print(render_table())
        return
    from repro.configs import registry
    cells = registry.cells() if args.all else [tuple(args.cell.split("."))]
    for arch, shape in cells:
        name = f"{arch}.{shape}" + ("" if args.sell == "dense"
                                    else f".{args.sell}")
        path = os.path.join(RESULTS_DIR, name + ".json")
        if args.all and os.path.exists(path):
            print(f"[cached] {name}")
            continue
        rec = analyze_cell(arch, shape, args.sell)
        if rec.get("status") != "ok":
            print(f"[{rec.get('status')}] {name}")
            continue
        print(f"[ok] {name} dominant={rec['dominant']} "
              f"cmp={rec['compute_s']:.2e} mem={rec['memory_s']:.2e} "
              f"col={rec['collective_s']:.2e} "
              f"frac={rec['roofline_fraction']:.1%} ({rec['analyze_s']}s)",
              flush=True)


if __name__ == "__main__":
    main()
