"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows (value semantics per bench:
microseconds for timing benches, counts for Table 1, MSE for Figure 3).
The roofline analysis (deliverable g) is its own module: benchmarks.roofline.
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced iteration counts (CI)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,table1,fig3,serve,kernels")
    args = ap.parse_args()
    which = set((args.only or "fig2,table1,fig3").split(","))

    print("name,us_per_call,derived")
    if "fig2" in which:
        from benchmarks import bench_fig2_speed
        bench_fig2_speed.main(csv=True)
        sys.stdout.flush()
    if "table1" in which:
        from benchmarks import bench_table1_params
        bench_table1_params.main(csv=True)
        sys.stdout.flush()
    if "fig3" in which:
        from benchmarks import bench_fig3_recovery
        bench_fig3_recovery.main(csv=True, steps=300 if args.quick else 3000)
        sys.stdout.flush()
    if "serve" in which:
        from benchmarks import bench_serve
        bench_serve.main(csv=True, argv=[])
        sys.stdout.flush()
    if "kernels" in which:
        from benchmarks import bench_kernels
        bench_kernels.main(csv=True, argv=["--quick"] if args.quick else [])
        sys.stdout.flush()


if __name__ == "__main__":
    main()
