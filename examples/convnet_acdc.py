"""Paper section 6.2 mechanism, offline proxy: replace the FC layers of a
small convnet with a 12-layer ACDC+ReLU+permutation stack and train on a
synthetic image-classification task.

CaffeNet/ImageNet itself is out of scope in an offline container; this
driver reproduces every *mechanism* of the paper's experiment: the 12-deep
SELL stack, identity+noise init, bias-on-D, lr multipliers (x24 A, x12 D),
no weight decay on the diagonals, and the parameter bookkeeping.

    PYTHONPATH=src python examples/convnet_acdc.py [--fc dense|acdc] \
        [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acdc as A
from repro.optim import OptimizerConfig, make_optimizer, step_decay_schedule
from repro.optim.optimizers import tree_add

N_CLASSES = 10
IMG = 16
N_FEAT = 1152   # 8x 12x12 after conv+pool... computed below


def synth_images(rng, n, n_classes=N_CLASSES):
    """Class-conditional Gabor-ish patterns + noise: linearly separable
    enough to train, hard enough to need the features."""
    keys = jax.random.split(rng, 3)
    labels = jax.random.randint(keys[0], (n,), 0, n_classes)
    xx, yy = jnp.meshgrid(jnp.arange(IMG), jnp.arange(IMG))
    freqs = (1 + jnp.arange(n_classes, dtype=jnp.float32)) / n_classes
    base = jnp.sin(freqs[:, None, None] * (xx + 2 * yy)[None] * 0.8)
    x = base[labels] + 0.3 * jax.random.normal(keys[1], (n, IMG, IMG))
    return x[..., None], labels


def init_model(rng, fc_kind="acdc", k=12):
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    p = {
        "conv1": 0.1 * jax.random.normal(r1, (3, 3, 1, 8)),
        "conv2": 0.1 * jax.random.normal(r2, (3, 3, 8, 8)),
    }
    n_feat = 8 * (IMG // 2) * (IMG // 2)  # 512
    if fc_kind == "dense":
        p["fc1"] = {"w": 0.05 * jax.random.normal(r3, (n_feat, n_feat)),
                    "b": jnp.zeros((n_feat,))}
    else:
        cfg = A.ACDCConfig(n=n_feat, k=k, relu=True, permute=True, bias=True,
                           init_mean=1.0, init_std=0.061)  # paper's init
        p["sell"] = A.init_acdc_params(r3, cfg)
        p["_cfg"] = None  # placeholder, cfg is static
    p["out"] = {"w": 0.05 * jax.random.normal(r4, (n_feat, N_CLASSES)),
                "b": jnp.zeros((N_CLASSES,))}
    return p, n_feat


def forward(p, x, fc_kind, cfg):
    h = jax.lax.conv_general_dilated(
        x, p["conv1"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.relu(h)
    h = jax.lax.conv_general_dilated(
        h, p["conv2"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.relu(h)
    h = h.reshape(h.shape[0], -1)
    h = h * 0.1  # paper: scale features into the SELL by 0.1
    if fc_kind == "dense":
        h = jax.nn.relu(h @ p["fc1"]["w"] + p["fc1"]["b"])
    else:
        h = jax.nn.relu(A.acdc_cascade(p["sell"], h, cfg))
    return h @ p["out"]["w"] + p["out"]["b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fc", default="acdc", choices=["acdc", "dense"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=12)
    args = ap.parse_args()

    rng = jax.random.PRNGKey(0)
    p, n_feat = init_model(rng, args.fc, args.k)
    p.pop("_cfg", None)
    cfg = A.ACDCConfig(n=n_feat, k=args.k, relu=True, permute=True,
                       bias=True, init_std=0.061)

    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    fc_params = (n_feat * n_feat + n_feat if args.fc == "dense"
                 else cfg.param_count())
    print(f"fc={args.fc}: total params {n_params:,} "
          f"(fc block: {fc_params:,})")

    # paper's optimizer: SGD momentum 0.65, step decay, lr mults x24/x12
    groups = ((r"sell/a$", {"lr_mult": 24.0, "weight_decay": 0.0}),
              (r"sell/d$", {"lr_mult": 12.0, "weight_decay": 0.0}),
              (r"sell/bias$", {"weight_decay": 0.0}))
    opt = make_optimizer(
        OptimizerConfig(kind="sgd", lr=1.0, momentum=0.65,
                        weight_decay=5e-4, grad_clip=1.0, groups=groups),
        step_decay_schedule(1e-3, 0.1, max(args.steps // 2, 1)))
    opt_state = opt.init(p)

    def loss_fn(p, x, y):
        logits = forward(p, x, args.fc, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1)), logits

    @jax.jit
    def step(p, opt_state, i, rng):
        x, y = synth_images(rng, args.batch)
        (l, logits), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        u, opt_state = opt.update(g, opt_state, p, i)
        return tree_add(p, u), opt_state, l, acc

    t0 = time.time()
    for i in range(args.steps):
        p, opt_state, l, acc = step(p, opt_state, jnp.asarray(i),
                                    jax.random.fold_in(rng, i))
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(l):.4f} acc {float(acc):.3f} "
                  f"({time.time()-t0:.0f}s)")
    xe, ye = synth_images(jax.random.PRNGKey(123), 512)
    logits = forward(p, xe, args.fc, cfg)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == ye).astype(jnp.float32)))
    print(f"eval acc: {acc:.3f}")


if __name__ == "__main__":
    main()
