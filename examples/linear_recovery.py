"""Paper section 6.1: recover a dense operator with ACDC cascades (Fig. 3).

    PYTHONPATH=src python examples/linear_recovery.py [--ks 1,4,16] \
        [--steps 3000] [--init good|bad]

Prints final train MSE per K; with --init bad reproduces the failure mode
of standard N(0, sigma) initialization on deep cascades (Fig. 3 right).
"""

import argparse

from benchmarks import bench_fig3_recovery as fig3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", default="1,2,4,8,16,32")
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--init", default="good", choices=["good", "bad", "both"])
    args = ap.parse_args()
    ks = [int(k) for k in args.ks.split(",")]

    from repro.core import acdc as A
    x, y, w = fig3.make_problem()
    import jax.numpy as jnp
    floor = float(jnp.mean((y - x @ w) ** 2))
    print(f"noise floor (dense W_true): {floor:.6f}")
    for k in ks:
        if args.init in ("good", "both"):
            loss, _ = fig3.train(
                A.ACDCConfig(n=fig3.N, k=k, bias=True,
                             init_mean=1.0, init_std=1e-1),
                x, y, steps=args.steps)
            print(f"K={k:2d}  init N(1,1e-1): final MSE {loss:.6f}")
        if args.init in ("bad", "both"):
            loss, _ = fig3.train(
                A.ACDCConfig(n=fig3.N, k=k, bias=True,
                             init_mean=0.0, init_std=1e-3),
                x, y, steps=args.steps)
            print(f"K={k:2d}  init N(0,1e-3): final MSE {loss:.6f}")


if __name__ == "__main__":
    main()
