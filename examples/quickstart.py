"""Quickstart: the ACDC structured efficient linear layer in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Shows: (1) a single ACDC layer and its O(N) parameter count, (2) a deep
cascade approximating a dense matrix, (3) dropping ACDC into a transformer
via the config system, (4) the fused Pallas kernel path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acdc as A
from repro.core.sell import SellConfig, init_sell_params, structured_linear


def main():
    rng = jax.random.PRNGKey(0)
    n = 512

    # -- 1. one ACDC layer: y = (x*a) C diag(d) C^T ------------------------
    cfg1 = A.ACDCConfig(n=n, k=1)
    params = A.init_acdc_params(rng, cfg1)
    x = jax.random.normal(rng, (8, n))
    y = A.acdc_cascade(params, x, cfg1)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[1] ACDC layer N={n}: {n_params} params "
          f"(dense would use {n*n}) -> {n*n // n_params}x smaller; "
          f"y shape {y.shape}")

    # -- 2. deep cascade as a drop-in dense replacement ---------------------
    cfg12 = A.ACDCConfig(n=n, k=12, relu=True, permute=True)
    p12 = A.init_acdc_params(rng, cfg12)
    y12 = A.acdc_cascade(p12, x, cfg12)
    n12 = cfg12.param_count()
    print(f"[2] 12-layer ACDC+ReLU+perm stack (the CaffeNet replacement): "
          f"{n12} params, output {y12.shape}")

    # -- 3. SELL dispatch: rectangular projection, any baseline -------------
    scfg = SellConfig(kind="acdc", n_in=768, n_out=3072, k=2,
                      lane_multiple=128)
    sp = init_sell_params(rng, scfg)
    h = structured_linear(sp, jax.random.normal(rng, (4, 768)), scfg)
    print(f"[3] rectangular 768->3072 ACDC (pad/truncate): {h.shape}, "
          f"{scfg.param_count()} params vs dense {768*3072}")

    # -- 4. fused Pallas kernel (interpret mode on CPU, MXU path on TPU) ----
    from repro.kernels import ops
    a = 1 + 0.1 * jax.random.normal(rng, (256,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(rng, 1), (256,))
    xk = jax.random.normal(rng, (16, 256))
    yk = ops.acdc_fused_op(xk, a, d, None)
    yr = A.acdc(xk, a, d, method="matmul")
    err = float(jnp.abs(yk - yr).max())
    print(f"[4] fused kernel vs reference: max |err| = {err:.2e}")

    # -- 5. inside a real model ---------------------------------------------
    import dataclasses
    from repro.configs import registry
    from repro.models import get_model
    cfg = dataclasses.replace(registry.get_smoke_config("qwen3_1_7b"),
                              sell_kind="acdc", sell_k=2)
    model = get_model(cfg)
    p = model.init(rng, cfg)
    toks = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    logits = model.apply(p, toks, cfg)
    print(f"[5] qwen3-smoke with ACDC projections: logits {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
