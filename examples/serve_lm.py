"""Batched serving example: prefill + decode with KV cache on any arch.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2_1_3b --smoke
"""

import sys

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "qwen3_1_7b", "--smoke",
                            "--batch", "4", "--prompt-len", "16",
                            "--gen", "24"]
    serve_mod.main(argv)
