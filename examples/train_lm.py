"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
dense vs ACDC projections, on the synthetic Markov-Zipf stream.

    PYTHONPATH=src python examples/train_lm.py --sell acdc --steps 200

This is the deliverable-(b) end-to-end example: real config, sharded state
(host mesh), checkpointing, straggler monitor — the same launcher code the
cluster run uses, exercised at ~100M scale on CPU.
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sell", default="dense", choices=["dense", "acdc",
                                                        "fastfood",
                                                        "circulant",
                                                        "low_rank"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # qwen3_1_7b smoke is tiny; build a ~100M variant instead: the full
    # qwen3 architecture at reduced depth/width via CLI overrides.
    import dataclasses
    from repro.configs import registry

    cfg = dataclasses.replace(
        registry.get_config("qwen3_1_7b"),
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32000, dtype="float32",
    )

    # ~100M check
    import jax
    import numpy as np
    from repro.models import get_model
    probe = jax.eval_shape(
        lambda r: get_model(cfg).init(r, cfg), jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(probe))
    print(f"model: {n/1e6:.1f}M params ({args.sell} projections)")

    # monkey-patch the launcher's config resolution to use our ~100M cfg
    orig = registry.get_smoke_config
    registry.get_smoke_config = lambda a: cfg
    try:
        train_mod.main([
            "--arch", "qwen3_1_7b", "--smoke",
            "--sell", args.sell,
            "--steps", str(args.steps),
            "--seq-len", str(args.seq_len),
            "--global-batch", str(args.global_batch),
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100",
            "--log-every", "10",
        ])
    finally:
        registry.get_smoke_config = orig


if __name__ == "__main__":
    main()
