"""Fault-tolerant checkpointing: atomic step dirs, keep-k GC, async writes,
and ELASTIC restore (resharding onto a different mesh).

Layout::

    <root>/step_00001000.tmp/...   (written, then atomically renamed)
    <root>/step_00001000/
        manifest.json              tree structure + shapes + dtypes
        arrays/<leaf-path>.npy     one file per leaf (mesh-agnostic layout)

Design points for 1000+ nodes:

* arrays are saved in GLOBAL layout (gathered per-leaf); a restarted job
  with a different (data, model) mesh re-shards on load via device_put with
  the new NamedSharding — elastic scaling without a conversion tool.
  (On a real multi-host cluster each host writes only the shards it owns —
  ocdbt-style; the single-process container exercises the same code path
  with world_size=1.)
* writes go to ``.tmp`` then ``os.replace`` — a preempted job can never
  leave a half-written "latest" checkpoint.
* ``save_async`` hands the gathered arrays to a writer thread so the train
  loop keeps stepping during I/O (straggler/jitter mitigation).
* keep-k garbage collection bounds disk usage.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            key = getattr(p, "key", getattr(p, "idx", None))
            parts.append(str(key))
        out.append(("/".join(parts), leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save -------------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Blocking save. Gathers leaves to host then writes atomically."""
        leaves, _ = _flatten_with_paths(state)
        host = [(p, np.asarray(jax.device_get(l))) for p, l in leaves]
        self._write(step, host, extra or {})

    def save_async(self, step: int, state: Any, extra: Optional[dict] = None):
        """Non-blocking: device->host copy happens now, file I/O in a
        background thread (joined on the next save or wait())."""
        self.wait()
        leaves, _ = _flatten_with_paths(state)
        host = [(p, np.asarray(jax.device_get(l))) for p, l in leaves]
        extra = dict(extra or {})
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, extra: dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        arrays = os.path.join(tmp, "arrays")
        os.makedirs(arrays, exist_ok=True)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for path, arr in host_leaves:
            fname = path.replace("/", "__") + ".npy"
            np.save(os.path.join(arrays, fname), arr)
            manifest["leaves"].append(
                {"path": path, "file": fname,
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a state tree or its
        eval_shape).  ``shardings`` (same structure, NamedShardings) enables
        ELASTIC restore onto any mesh: each leaf is device_put with its new
        sharding regardless of the mesh it was saved under."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        leaves, treedef = _flatten_with_paths(like)
        shard_leaves = None
        if shardings is not None:
            shard_leaves = [s for _, s in _flatten_with_paths(shardings)[0]]
        out = []
        for i, (path, leaf) in enumerate(leaves):
            entry = by_path.get(path)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {path!r}")
            arr = np.load(os.path.join(d, "arrays", entry["file"]))
            want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def extra(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f).get("extra", {})
