"""Assigned architecture configs (one module per arch) + registry."""

from repro.configs.registry import ARCHS, SHAPES, get_config, get_smoke_config, input_specs  # noqa: F401
