"""ChatGLM3-6B — dense decoder with 2d (half-dim) RoPE, GQA kv=2
[arXiv:2406.12793; hf].

28L, d_model 4096, 32 heads (GQA kv=2), d_ff 13696, vocab 65024.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="decoder",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope_theta=10000.0,
    rope_fraction=0.5,     # "RoPE 2d": rotary applied to half the head dims
    mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=224, vocab_size=512, dtype="float32",
)
