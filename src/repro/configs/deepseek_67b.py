"""DeepSeek-67B — dense llama-arch decoder [arXiv:2401.02954; hf].

95L, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 102400.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="decoder",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10000.0,
    mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=128, n_heads=8, n_kv_heads=1, head_dim=16,
    d_ff=352, vocab_size=512, dtype="float32",
)
