"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].

28L, d_model 2048, 16 heads (GQA kv=16), expert d_ff 1408, vocab 102400.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="decoder",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, head_dim=16,
    d_ff=64, vocab_size=512, n_experts=8, n_shared_experts=1, top_k=2,
    dtype="float32",
)
