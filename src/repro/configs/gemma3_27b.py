"""Gemma3-27B — dense decoder, 5:1 local:global attention, 128k context
[hf:google/gemma-3-*; unverified tier].

62L, d_model 5376, 32 heads (GQA kv=16), d_ff 21504, vocab 262144.
Sliding window 1024 on local layers; every 6th layer global; qk-norm.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="decoder",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,        # 5 local : 1 global
    qk_norm=True,
    mlp_act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
    d_ff=384, vocab_size=512, sliding_window=8, dtype="float32",
)
