"""LLaVA-NeXT-34B — VLM; transformer BACKBONE only (anyres vision tower is
a STUB providing patch embeddings) [hf:llava-hf/*; unverified tier].

60L, d_model 7168, 56 heads (GQA kv=8), d_ff 20480, vocab 64000;
576 patch-embedding prefix tokens from the stub frontend.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="decoder",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    frontend="vision",
    n_frontend_tokens=576,
    mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, n_frontend_tokens=8, dtype="float32",
)
