"""Mamba2-1.3B — attention-free SSD state-space model [arXiv:2405.21060].

48L, d_model 2048, d_inner 4096, ssm_state 128, head_dim 64, vocab 50280.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    d_inner=4096,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=128, d_inner=256, ssm_state=16,
    ssm_head_dim=32, ssm_chunk=8, vocab_size=512, dtype="float32",
)
