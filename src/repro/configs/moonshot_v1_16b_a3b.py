"""Moonshot-v1-16B-A3B (Moonlight) — fine-grained MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

48L, d_model 2048, 16 heads (GQA kv=16), expert d_ff 1408, vocab 163840,
64 routed experts top-6 + 2 shared experts.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="decoder",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, head_dim=16,
    d_ff=64, vocab_size=512, n_experts=8, n_shared_experts=1, top_k=2,
    dtype="float32",
)
