"""Qwen3-1.7B — dense decoder with qk-norm, GQA [hf:Qwen/Qwen3-*; hf].

28L, d_model 2048, 16 heads (GQA kv=8), d_ff 6144, vocab 151936.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="decoder",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
    d_ff=256, vocab_size=512, dtype="float32",
)
