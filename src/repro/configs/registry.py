"""Architecture registry + (arch x shape) dry-run cell definitions.

Ten assigned architectures, each with the four LM shape cells:

    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (prefill forward)
    decode_32k   cache 32768, global_batch 128  (serve_step, 1 new token)
    long_500k    cache 524288, global_batch 1   (serve_step; sub-quadratic
                                                 archs only, see skips())

``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input of the step being lowered — no device allocation.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCHS: Tuple[str, ...] = (
    "deepseek_67b",
    "chatglm3_6b",
    "gemma3_27b",
    "qwen3_1_7b",
    "seamless_m4t_large_v2",
    "mamba2_1_3b",
    "moonshot_v1_16b_a3b",
    "deepseek_moe_16b",
    "zamba2_1_2b",
    "llava_next_34b",
)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

# archs whose attention is sub-quadratic (SSM / hybrid / 5:1 sliding
# window) run long_500k; pure full-attention archs skip it (DESIGN.md §4).
LONG_CONTEXT_OK = {"mamba2_1_3b", "zamba2_1_2b", "gemma3_27b"}


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def skips(arch: str, shape: str) -> Optional[str]:
    """Reason string if this (arch, shape) cell is skipped, else None."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("pure full-attention config: 524k-token quadratic attention "
                "is out of contract; run on SSM/hybrid/sliding-window archs")
    return None


def cells(include_skipped: bool = False):
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if include_skipped or skips(a, s.name) is None:
                out.append((a, s.name))
    return out


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE


def with_sell(
    cfg: ModelConfig,
    kind: str,
    *,
    method: str = "auto",
    transform: str = "acdc",
) -> ModelConfig:
    """Return ``cfg`` with its projections swapped for a SELL variant.

    Shared by the train/serve launchers so every entry point spells SELL
    overrides identically.  ``kind='dense'`` is the no-op baseline;
    ``transform`` picks the cascade's transform family (core/families.py,
    only meaningful for ``kind='acdc'``).  The transform name is validated
    here, at config-build time, so a typo fails before any tracing starts.
    """
    if kind == "dense":
        return cfg
    from repro.core import families as families_mod

    families_mod.get_family(transform)  # raises with the registered list
    return dataclasses.replace(
        cfg, sell_kind=kind, sell_method=method, sell_transform=transform)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for the dry-run.
# ---------------------------------------------------------------------------

def _frontend_tokens(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.frontend == "audio":
        return max(seq_len // 4, 8)      # ~4x temporal downsampling stub
    if cfg.frontend == "vision":
        return cfg.n_frontend_tokens or 576
    return 0


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict:
    """Inputs for the step kind of this cell.

    train   -> {"batch": {tokens, labels[, frontend_embeds]}}
    prefill -> {"tokens" [, "frontend_embeds"]}
    decode  -> {"tokens", "position"} (cache specs come from init_cache)
    """
    b, s = shape.global_batch, shape.seq_len
    f = _frontend_tokens(cfg, s)
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if f:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, f, cfg.d_model), jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if f:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, f, cfg.d_model), jnp.float32)
        return out
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "position": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    raise ValueError(shape.kind)
