"""SeamlessM4T-large-v2 — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf].  Audio frontend is a STUB: precomputed frame
embeddings feed the encoder (input_specs provides them).

24L encoder + 24L decoder, d_model 1024, 16 heads, d_ff 8192, vocab 256206.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
    mlp_act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_encoder_layers=2, d_model=128, n_heads=8,
    n_kv_heads=8, head_dim=16, d_ff=256, vocab_size=512,
    n_frontend_tokens=16, dtype="float32",
)
