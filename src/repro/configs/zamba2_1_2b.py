"""Zamba2-1.2B — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

38 mamba layers, d_model 2048, ssm_state 64; shared attention block
(32 heads, d_ff 8192) applied every 6 layers; vocab 32000.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    d_inner=4096,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=8, n_kv_heads=8, head_dim=16,
    d_ff=256, d_inner=256, ssm_state=16, ssm_head_dim=32, ssm_chunk=8,
    attn_every=2, vocab_size=512, dtype="float32",
)
