"""Core contribution of the paper: the ACDC structured efficient linear
layer, its deep cascades, and the SELL baseline zoo it is compared to.

The cascade's transform ``C`` is pluggable: any :class:`TransformFamily`
registered in :mod:`repro.core.families` supplies the orthonormal matrix
pair, the O(N log N) fast apply/inverse, the riffle policy and the
identity-init recipe.  Registered families:

==========  =====================  ==========  ============  ===============
family      transform C            param       size rule     identity init
==========  =====================  ==========  ============  ===============
acdc        DCT-II (orthonormal)   real diag   any N         N(1, std^2)
circulant   real-DFT basis         real diag   any N         N(1, std^2)
hadamard    Walsh-Hadamard / sqrt  real diag   N = 2^p       N(1, std^2)
==========  =====================  ==========  ============  ===============

All three satisfy ``C^-1 = C^T`` (real orthonormal), which is the only
property the paper's backward (eqs. 10-14) and the fused Pallas kernels
rely on — so every family gets the fused forward/backward cascade kernels
for free.  The ``afdf`` SELL kind (complex diagonals) stays a separate
theory oracle in :mod:`repro.core.sell`; it is not a registry family
because its diagonals are complex and the MXU path must stay real.

NOTE: the single-layer function ``repro.core.acdc.acdc`` is intentionally
NOT re-exported at package level — it would shadow the ``acdc`` submodule.
"""

from repro.core.acdc import (  # noqa: F401
    ACDCConfig,
    acdc_cascade,
    acdc_cascade_dense_equivalent,
    acdc_rectangular,
    init_acdc_params,
)
from repro.core.families import (  # noqa: F401
    TransformFamily,
    get_family,
)
from repro.core.sell import (  # noqa: F401
    SellConfig,
    init_sell_params,
    sell_dense_equivalent,
    structured_linear,
)
