"""Core contribution of the paper: the ACDC structured efficient linear
layer, its deep cascades, and the SELL baseline zoo it is compared to.

NOTE: the single-layer function ``repro.core.acdc.acdc`` is intentionally
NOT re-exported at package level — it would shadow the ``acdc`` submodule.
"""

from repro.core.acdc import (  # noqa: F401
    ACDCConfig,
    acdc_cascade,
    acdc_cascade_dense_equivalent,
    acdc_rectangular,
    init_acdc_params,
)
from repro.core.sell import (  # noqa: F401
    SellConfig,
    init_sell_params,
    sell_dense_equivalent,
    structured_linear,
)
