"""The ACDC structured efficient linear layer (paper sections 3-4).

A single ACDC layer computes (row-vector convention, as in the paper)::

    y = x . A . C . D . C^-1

with ``A = diag(a)``, ``D = diag(d)`` learned real diagonals and ``C`` an
orthonormal transform — the paper's DCT-II by default, or any registered
:mod:`repro.core.families` family (``family='circulant'`` swaps in the
real-DFT basis, ``'hadamard'`` the normalized Walsh-Hadamard).  O(N)
parameters, O(N log N) FLOPs for every family.

This module provides:

* ``acdc`` — one layer, selectable transform backend (FFT / matmul / Pallas).
* ``init_acdc_params`` / ``acdc_cascade`` — the order-K deep SELL
  (Definition 1) with the paper's identity+noise initialization, optional
  interleaved ReLU non-linearities, riffle permutations and bias-on-D
  (the CaffeNet configuration of section 6.2).
* ``acdc_rectangular`` — pad/truncate wrapper for ``N_in != N_out`` layers
  (Deep-Fried-Convnets-style), used when ACDC replaces rectangular
  projections inside the model zoo.

Parameters are plain pytrees (dicts of arrays) so they can be stacked for
``jax.lax.scan`` and sharded with pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import families as families_mod

Method = Literal["auto", "fft", "matmul", "pallas"]

# N at or below which the explicit-matrix (MXU) path is preferred on TPU.
# Above it the FFT path wins on FLOPs; the Pallas kernels are always
# matrix-based and use their own VMEM gate (kernels.acdc_fused.MAX_FUSED_N)
# instead of this crossover.  On CPU (tests) "auto" resolves to fft for
# large N.
MATMUL_MAX_N = 4096


# ---------------------------------------------------------------------------
# Single layer.
# ---------------------------------------------------------------------------

def _resolve_method(n: int, method: Method) -> str:
    if method != "auto":
        return method
    return "matmul" if n <= MATMUL_MAX_N else "fft"


def acdc(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    method: Method = "auto",
    family: str = "acdc",
) -> jax.Array:
    """One structured layer ``y = ((x*a) C * d + bias) C^-1`` along the
    last axis of ``x``, with ``C`` drawn from the ``family`` registry.

    ``bias`` (if given) is the paper's bias-on-D: added after the ``D``
    scaling, in the transform domain, before the inverse transform.
    """
    n = x.shape[-1]
    if a.shape[-1] != n or d.shape[-1] != n:
        raise ValueError(f"diagonal size mismatch: x={n} a={a.shape} d={d.shape}")
    fam = families_mod.get_family(family)
    m = _resolve_method(n, method)
    if m == "pallas":
        # fp32 master diagonals go to the kernel UNCAST: it upcasts every
        # operand to fp32 in VMEM anyway, so a bf16 round trip on a/d/bias
        # here would shed mantissa bits for free.  Only the activation
        # dtype (x) decides the output dtype.
        from repro.kernels import ops as kernel_ops

        return kernel_ops.acdc_fused_op(x, a, d, bias, family=family)
    # jnp fft/matmul paths carry the activation dtype: fp32 master
    # diagonals are cast down so a bf16 residual stream stays bf16
    # through the cascade (scan carries).
    a = a.astype(x.dtype)
    d = d.astype(x.dtype)
    bias = bias.astype(x.dtype) if bias is not None else None
    h1 = x * a
    if m == "matmul":
        h2 = jnp.matmul(h1, fam.matrix(n, x.dtype))
    else:
        h2 = fam.apply(h1)
    h3 = h2 * d
    if bias is not None:
        h3 = h3 + bias
    if m == "matmul":
        y = jnp.matmul(h3, fam.inverse_matrix(n, x.dtype))
    else:
        y = fam.inverse(h3)
    return y


# ---------------------------------------------------------------------------
# Cascade (order-K deep SELL).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ACDCConfig:
    """Configuration of an order-K structured-transform cascade."""

    n: int                       # feature size
    k: int = 1                   # number of stacked ACDC layers
    relu: bool = False           # interleave ReLU between layers (not after last)
    permute: bool = False        # riffle-permute between layers for incoherence
    bias: bool = True            # bias-on-D (paper section 6.2)
    init_mean: float = 1.0       # paper: N(1, sigma^2) "identity + noise"
    init_std: float = 0.061      # paper section 6.2 value
    first_a_identity: bool = False  # Definition 1 convention A_1 = I
    method: Method = "auto"
    family: str = "acdc"         # transform family (core/families.py)

    def param_count(self) -> int:
        per = 2 * self.n + (self.n if self.bias else 0)
        return per * self.k


def init_acdc_params(rng: jax.Array, cfg: ACDCConfig, dtype=jnp.float32) -> dict:
    """Stacked cascade parameters: each leaf has leading dim ``k``.

    Initialization delegates to the family's identity-init recipe; the
    default is the paper's diagonals ~ N(init_mean, init_std^2)
    (identity + symmetry-breaking noise).  Biases start at zero.
    """
    fam = families_mod.get_family(cfg.family)
    a, d = fam.init_diagonals(rng, cfg.k, cfg.n, cfg.init_mean,
                              cfg.init_std, dtype)
    if cfg.first_a_identity:
        a = a.at[0].set(jnp.ones((cfg.n,), dtype))
    params = {"a": a, "d": d}
    if cfg.bias:
        params["bias"] = jnp.zeros((cfg.k, cfg.n), dtype)
    return params


def acdc_cascade(params: dict, x: jax.Array, cfg: ACDCConfig) -> jax.Array:
    """Apply the order-K cascade with optional ReLU + riffle interleaving.

    Uses ``lax.scan`` over the stacked layer parameters so the compiled
    program is O(1) in K.
    """
    n = cfg.n
    if cfg.k > 1 and _resolve_method(n, cfg.method) == "pallas":
        # Whole-cascade fusion: one Pallas kernel walks all K layers with
        # the activation row-block resident in VMEM (8N bytes/row instead
        # of 8KN), ReLU/riffle interleavings included.  The cascade-level
        # custom VJP's primary backward is the reverse-sweep kernel
        # (kernels/acdc_cascade_bwd): one call, cotangent resident in
        # VMEM, layer inputs recomputed on-chip — 12N bytes/row
        # independent of K.  Each direction falls back internally to the
        # per-layer scan when its own VMEM budget is exceeded (the
        # backward's includes a (K-1)-deep activation stash, so it can
        # fall back while the forward stays fused).
        from repro.kernels import ops as kernel_ops

        return kernel_ops.acdc_cascade_op(
            x, params["a"], params["d"], params.get("bias"),
            relu=cfg.relu, permute=cfg.permute, family=cfg.family)
    fam = families_mod.get_family(cfg.family)
    perm = jnp.asarray(fam.riffle(n)) if cfg.permute else None

    if cfg.k == 1:
        layer0 = jax.tree.map(lambda p: p[0], params)
        return acdc(x, layer0["a"], layer0["d"], layer0.get("bias"),
                    method=cfg.method, family=cfg.family)

    # Interleavings (ReLU / permutation) apply BETWEEN layers, not after the
    # last one, matching the paper's CaffeNet stack.
    def scan_body(h, layer):
        y = acdc(h, layer["a"], layer["d"], layer.get("bias"),
                 method=cfg.method, family=cfg.family)
        if cfg.relu:
            y = jax.nn.relu(y)
        if perm is not None:
            y = y[..., perm]
        return y, None

    # all but last through scan with interleaving; final layer plain.
    head = jax.tree.map(lambda p: p[:-1], params)
    last = jax.tree.map(lambda p: p[-1], params)
    h, _ = jax.lax.scan(scan_body, x, head)
    return acdc(h, last["a"], last["d"], last.get("bias"),
                method=cfg.method, family=cfg.family)


def acdc_cascade_dense_equivalent(params: dict, cfg: ACDCConfig) -> jax.Array:
    """Materialize the cascade as an explicit N x N matrix (test oracle).

    Only valid for linear cascades (no ReLU).
    """
    if cfg.relu:
        raise ValueError("dense equivalent undefined with interleaved ReLU")
    eye = jnp.eye(cfg.n, dtype=jnp.float32)
    # Push the identity through the cascade: rows transform independently.
    return acdc_cascade(jax.tree.map(lambda p: p.astype(jnp.float32), params), eye, cfg)


# ---------------------------------------------------------------------------
# Rectangular wrapper (Deep-Fried style pad/truncate).
# ---------------------------------------------------------------------------

def rectangular_size(n_in: int, n_out: int, multiple: int = 1) -> int:
    """Operating size for a rectangular ACDC: max(in, out) padded to a lane
    multiple (MXU alignment — see DESIGN.md section 3)."""
    n = max(n_in, n_out)
    return int(np.ceil(n / multiple) * multiple)


def acdc_rectangular(
    params: dict,
    x: jax.Array,
    cfg: ACDCConfig,
    n_in: int,
    n_out: int,
) -> jax.Array:
    """Apply a cascade as an ``n_in -> n_out`` map via zero-pad / truncate."""
    if x.shape[-1] != n_in:
        raise ValueError(f"expected last dim {n_in}, got {x.shape}")
    pad = cfg.n - n_in
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    y = acdc_cascade(params, x, cfg)
    return y[..., :n_out]
