"""Pluggable structured-transform families behind one registry.

The paper's layer is ``y = x . A . C . D . C^-1`` with ``C`` the DCT-II,
but nothing downstream of the transform choice cares WHICH ``C`` it is:
the backward formulas (paper eqs. 10-14), the fused Pallas kernel stack
(which already takes ``C``/``C^T`` as operands), and the identity+noise
init are all valid for any real matrix with ``C^-1 = C^T``.  A
:class:`TransformFamily` packages everything a structured-linear layer
needs to know about its transform:

* ``matrix`` / ``inverse_matrix`` — the explicit orthonormal ``N x N``
  operand pair (MXU matmul path, Pallas kernel operands, test oracle);
* ``apply`` / ``inverse``         — the fast O(N log N) functional path;
* ``complex_diagonals``           — diagonal parameterization (every
  registered family is real; the AFDF theory oracle in ``core/sell.py``
  stays a separate complex code path);
* ``riffle``                      — the between-layer permutation policy
  ("adjacent SELLs are incoherent", paper section 6.2);
* ``init_diagonals``              — the identity-init recipe (identity +
  symmetry-breaking noise works for any orthonormal ``C``);
* ``valid_size``                  — rounds a requested feature size up to
  one the transform supports (Hadamard needs powers of two).

Registered families:

====================  =======================  ===========================
name                  transform                notes
====================  =======================  ===========================
``acdc``              DCT-II (paper eq. 9)     the paper's layer,
                                               bit-identical to the
                                               pre-registry code path
``circulant``         real-DFT basis           diagonal-circulant networks
                      (2x2-block real form      (Araujo et al., 1901.10255)
                      of the FFT)               with the MXU path kept real
``hadamard``          Walsh-Hadamard / sqrt n  Fastfood's transform
                                               (Yang et al., 2015); sizes
                                               rounded up to powers of two
====================  =======================  ===========================

Follow-on candidates recorded in ROADMAP.md: matrix product operators
(Gao et al., 1904.06194) and DCT-perceptron conv layers (2211.08577).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transforms

__all__ = [
    "TransformFamily",
    "register",
    "get_family",
    "available",
    "default_init_diagonals",
]


def default_init_diagonals(rng: jax.Array, k: int, n: int, mean: float,
                           std: float, dtype=jnp.float32
                           ) -> Tuple[jax.Array, jax.Array]:
    """Paper section 6.2 identity+noise: a, d ~ N(mean, std^2), stacked
    ``(k, n)``.  ``C . C^-1 = I`` for any orthonormal family, so starting
    both diagonals near 1 starts every family's layer near identity.
    The split/normal call order is frozen: the ``acdc`` golden pins
    (tests/goldens) assert bit-identical streams from this exact code.
    """
    ra, rd = jax.random.split(rng)
    a = mean + std * jax.random.normal(ra, (k, n), dtype)
    d = mean + std * jax.random.normal(rd, (k, n), dtype)
    return a, d


def _identity_size(n: int) -> int:
    return n


def _next_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


@dataclasses.dataclass(frozen=True)
class TransformFamily:
    """Everything a structured linear layer needs about its transform."""

    name: str
    #: explicit orthonormal matrix C, row-vector convention y = x @ C
    matrix: Callable[..., jax.Array]
    #: C^-1 (= C^T for every registered family)
    inverse_matrix: Callable[..., jax.Array]
    #: fast O(N log N) y = x @ C along the last axis
    apply: Callable[[jax.Array], jax.Array]
    #: fast O(N log N) x = y @ C^-1 along the last axis
    inverse: Callable[[jax.Array], jax.Array]
    #: diagonal parameterization: False = real a/d (all registered
    #: families; the Pallas kernels require it)
    complex_diagonals: bool = False
    #: between-layer permutation policy (indices for size n)
    riffle: Callable[[int], np.ndarray] = transforms.make_riffle
    #: identity-init recipe -> (a, d), each (k, n)
    init_diagonals: Callable[..., Tuple[jax.Array, jax.Array]] = \
        default_init_diagonals
    #: rounds a requested size up to one the transform supports
    valid_size: Callable[[int], int] = _identity_size

    def matrices(self, n: int, dtype=jnp.float32
                 ) -> Tuple[jax.Array, jax.Array]:
        """The ``(C, C^-1)`` operand pair at size ``n``."""
        return self.matrix(n, dtype), self.inverse_matrix(n, dtype)


_REGISTRY: Dict[str, TransformFamily] = {}


def register(family: TransformFamily) -> TransformFamily:
    """Add a family to the registry (last registration wins, so tests can
    shadow); returns it so definitions read as assignments."""
    _REGISTRY[family.name] = family
    return family


def get_family(name: str) -> TransformFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transform family {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The built-in zoo.
# ---------------------------------------------------------------------------

ACDC = register(TransformFamily(
    name="acdc",
    matrix=transforms.dct_matrix,
    inverse_matrix=transforms.idct_matrix,
    apply=transforms.dct,
    inverse=transforms.idct,
))

CIRCULANT = register(TransformFamily(
    name="circulant",
    matrix=transforms.real_fft_matrix,
    inverse_matrix=transforms.real_ifft_matrix,
    apply=transforms.real_fft,
    inverse=transforms.real_ifft,
))

HADAMARD = register(TransformFamily(
    name="hadamard",
    matrix=transforms.hadamard_matrix,
    inverse_matrix=transforms.hadamard_matrix,  # involutive: H = H^-1
    apply=transforms.fwht,
    inverse=transforms.fwht,
    valid_size=_next_pow2,
))
