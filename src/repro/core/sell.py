"""Structured Efficient Linear Layer (SELL) zoo.

The paper positions ACDC inside a family of SELLs (its eq. 2 notation
``Phi(D, P, S, B)``).  To make the comparisons of Table 1 / Figure 4
reproducible end-to-end, every baseline the paper discusses is implemented
here behind one dispatch point, :func:`structured_linear`:

* ``dense``          — ordinary ``y = x W (+ b)``.
* ``low_rank``       — ``y = x U V`` with rank r (Sainath et al. 2013).
* ``circulant``      — adaptive variant of Cheng et al. 2015,
                       ``y = x diag(a) R`` with R circulant (learned first
                       column), computed via rFFT.
* ``fastfood``       — Adaptive Fastfood (Yang et al. 2015),
                       ``Phi = D1 H P D2 H D3`` with learned diagonals.
* ``acdc``           — the paper's layer (order-K cascade), see
                       :mod:`repro.core.acdc`.  With ``method='pallas'``
                       the whole cascade (ReLU/riffle interleavings
                       included) runs as one fused TPU kernel in EACH
                       direction — 8N bytes of HBM traffic per row
                       forward and 12N backward (the reverse-sweep VJP),
                       both regardless of K (``kernels.ops
                       .acdc_cascade_op``); the model zoo's projections
                       inherit this through ``models.linear.linear_apply``,
                       so the training step sits at the paper's roofline
                       end to end.
* ``afdf``           — the complex variant of section 3 (theory oracle).

All follow the row-vector convention ``y = x @ Phi`` on the last axis.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acdc as acdc_mod
from repro.core import families as families_mod

SellKind = Literal["dense", "low_rank", "circulant", "fastfood", "acdc", "afdf"]


@dataclasses.dataclass(frozen=True)
class SellConfig:
    """Config for one structured linear ``n_in -> n_out``.

    ``kind`` selects the SELL baseline; for ``kind='acdc'`` the
    ``transform`` field additionally selects the cascade's transform
    family from :mod:`repro.core.families` (``'acdc'`` = the paper's DCT,
    ``'circulant'`` = real-DFT basis, ``'hadamard'`` = Walsh-Hadamard).
    Note the distinction from ``kind='circulant'``: that is Cheng et
    al.'s learned-convolution baseline ``y = x diag(a) R``, while
    ``kind='acdc', transform='circulant'`` is the paper's A.C.D.C^-1
    cascade with the transform swapped for the real FFT basis.
    """

    kind: SellKind = "dense"
    n_in: int = 0
    n_out: int = 0
    # acdc / afdf
    k: int = 1
    relu: bool = False
    permute: bool = False
    bias: bool = True
    init_std: float = 0.061
    # 'pallas' routes order-K cascades through the whole-cascade fused
    # kernel (per-layer fallback above its VMEM budget); 'auto' picks
    # matmul/fft by size.
    method: acdc_mod.Method = "auto"
    # transform family for kind='acdc' cascades (core/families.py)
    transform: str = "acdc"
    # low-rank
    rank: int = 0
    # dense init
    dense_init_scale: float = 1.0
    # MXU lane alignment for the transform size; 1 = exact (paper-faithful
    # small experiments), 128 = TPU-aligned (model zoo).
    lane_multiple: int = 1

    @property
    def n_op(self) -> int:
        """Internal (padded square) operating size for transform SELLs.

        Lane alignment first, then the family's size rule on top (the
        Hadamard-based families need powers of two; DCT/real-FFT accept
        any size, so their rule is the identity).
        """
        if self.kind == "fastfood":
            n = max(self.n_in, self.n_out)
            return families_mod.get_family("hadamard").valid_size(n)
        n = acdc_mod.rectangular_size(self.n_in, self.n_out,
                                      self.lane_multiple)
        if self.kind == "acdc":
            n = families_mod.get_family(self.transform).valid_size(n)
        return n

    def param_count(self) -> int:
        n, ni, no = self.n_op, self.n_in, self.n_out
        if self.kind == "dense":
            return ni * no + (no if self.bias else 0)
        if self.kind == "low_rank":
            return self.rank * (ni + no) + (no if self.bias else 0)
        if self.kind == "circulant":
            return 2 * n + (no if self.bias else 0)
        if self.kind == "fastfood":
            return 3 * n + (no if self.bias else 0)
        if self.kind == "acdc":
            per = 2 * n + (n if self.bias else 0)
            return per * self.k
        if self.kind == "afdf":
            return 4 * n * self.k  # complex a, d = 2 reals each; no bias
        raise ValueError(self.kind)


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------

def init_sell_params(rng: jax.Array, cfg: SellConfig, dtype=jnp.float32) -> dict:
    n = cfg.n_op
    if cfg.kind == "dense":
        rw, rb = jax.random.split(rng)
        scale = cfg.dense_init_scale / np.sqrt(cfg.n_in)
        p = {"w": scale * jax.random.normal(rw, (cfg.n_in, cfg.n_out), dtype)}
        if cfg.bias:
            p["b"] = jnp.zeros((cfg.n_out,), dtype)
        return p
    if cfg.kind == "low_rank":
        ru, rv, rb = jax.random.split(rng, 3)
        su = 1.0 / np.sqrt(cfg.n_in)
        sv = 1.0 / np.sqrt(max(cfg.rank, 1))
        p = {
            "u": su * jax.random.normal(ru, (cfg.n_in, cfg.rank), dtype),
            "v": sv * jax.random.normal(rv, (cfg.rank, cfg.n_out), dtype),
        }
        if cfg.bias:
            p["b"] = jnp.zeros((cfg.n_out,), dtype)
        return p
    if cfg.kind == "circulant":
        ra, rc = jax.random.split(rng)
        # a ~ identity+noise; circulant first column ~ delta + noise so the
        # layer starts near identity (same philosophy as the ACDC init).
        a = 1.0 + cfg.init_std * jax.random.normal(ra, (n,), dtype)
        c = cfg.init_std * jax.random.normal(rc, (n,), dtype)
        c = c.at[0].add(1.0)
        p = {"a": a, "c": c}
        if cfg.bias:
            p["b"] = jnp.zeros((cfg.n_out,), dtype)
        return p
    if cfg.kind == "fastfood":
        r1, r2, r3 = jax.random.split(rng, 3)
        # NOTE: the fixed random permutation P is NOT a parameter — it is
        # derived deterministically from the layer size at apply time
        # (compile-time constant), keeping the param tree purely float.
        p = {
            "d1": 1.0 + cfg.init_std * jax.random.normal(r1, (n,), dtype),
            "d2": 1.0 + cfg.init_std * jax.random.normal(r2, (n,), dtype),
            "d3": 1.0 + cfg.init_std * jax.random.normal(r3, (n,), dtype),
        }
        if cfg.bias:
            p["b"] = jnp.zeros((cfg.n_out,), dtype)
        return p
    if cfg.kind == "acdc":
        acfg = _acdc_cfg(cfg)
        return acdc_mod.init_acdc_params(rng, acfg, dtype)
    if cfg.kind == "afdf":
        ra, rd = jax.random.split(rng)
        # complex diagonals stored as separate real/imag parts
        a_re = 1.0 + cfg.init_std * jax.random.normal(ra, (cfg.k, n), dtype)
        d_re = 1.0 + cfg.init_std * jax.random.normal(rd, (cfg.k, n), dtype)
        a_im = cfg.init_std * jax.random.normal(jax.random.fold_in(ra, 1), (cfg.k, n), dtype)
        d_im = cfg.init_std * jax.random.normal(jax.random.fold_in(rd, 1), (cfg.k, n), dtype)
        return {"a_re": a_re, "a_im": a_im, "d_re": d_re, "d_im": d_im}
    raise ValueError(cfg.kind)


def _acdc_cfg(cfg: SellConfig) -> acdc_mod.ACDCConfig:
    return acdc_mod.ACDCConfig(
        n=cfg.n_op,
        k=cfg.k,
        relu=cfg.relu,
        permute=cfg.permute,
        bias=cfg.bias,
        init_std=cfg.init_std,
        method=cfg.method,
        family=cfg.transform,
    )


# ---------------------------------------------------------------------------
# Apply.
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, n: int) -> jax.Array:
    pad = n - x.shape[-1]
    if pad:
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def structured_linear(params: dict, x: jax.Array, cfg: SellConfig) -> jax.Array:
    """Apply the configured SELL: ``x (..., n_in) -> y (..., n_out)``."""
    if cfg.kind == "dense":
        y = jnp.matmul(x, params["w"].astype(x.dtype))
        if cfg.bias:
            y = y + params["b"].astype(x.dtype)
        return y
    if cfg.kind == "low_rank":
        y = jnp.matmul(jnp.matmul(x, params["u"].astype(x.dtype)),
                       params["v"].astype(x.dtype))
        if cfg.bias:
            y = y + params["b"].astype(x.dtype)
        return y
    n = cfg.n_op
    h = _pad_to(x, n)
    if cfg.kind == "circulant":
        h = h * params["a"].astype(x.dtype)
        hf = jnp.fft.rfft(h.astype(jnp.float32), axis=-1)
        cf = jnp.fft.rfft(params["c"].astype(jnp.float32))
        y = jnp.fft.irfft(hf * cf, n=n, axis=-1).astype(x.dtype)
        y = y[..., : cfg.n_out]
        if cfg.bias:
            y = y + params["b"].astype(x.dtype)
        return y
    if cfg.kind == "fastfood":
        # The Hadamard applications route through the family registry
        # (same normalized fwht the 'hadamard' cascade family uses) — the
        # transform is shared, only the D1 H P D2 H D3 wiring is
        # Fastfood-specific.
        had = families_mod.get_family("hadamard")
        perm = jnp.asarray(
            np.random.RandomState(n).permutation(n).astype(np.int32))
        h = h * params["d3"].astype(x.dtype)
        h = had.apply(h)
        h = h * params["d2"].astype(x.dtype)
        h = jnp.take(h, perm, axis=-1)
        h = had.apply(h)
        h = h * params["d1"].astype(x.dtype)
        y = h[..., : cfg.n_out]
        if cfg.bias:
            y = y + params["b"].astype(x.dtype)
        return y
    if cfg.kind == "acdc":
        acfg = _acdc_cfg(cfg)
        return acdc_mod.acdc_rectangular(params, x, acfg, cfg.n_in, cfg.n_out)
    if cfg.kind == "afdf":
        hc = h.astype(jnp.complex64)
        for i in range(cfg.k):
            a = (params["a_re"][i] + 1j * params["a_im"][i]).astype(jnp.complex64)
            d = (params["d_re"][i] + 1j * params["d_im"][i]).astype(jnp.complex64)
            hc = hc * a
            hc = jnp.fft.fft(hc, axis=-1)
            hc = hc * d
            hc = jnp.fft.ifft(hc, axis=-1)
        return hc[..., : cfg.n_out]
    raise ValueError(cfg.kind)


def sell_dense_equivalent(params: dict, cfg: SellConfig) -> jax.Array:
    """Materialize any *linear* SELL as an explicit (n_in, n_out) matrix."""
    if cfg.relu:
        raise ValueError("dense equivalent undefined with ReLU")
    eye = jnp.eye(cfg.n_in, dtype=jnp.float32)
    out = structured_linear(jax.tree.map(lambda p: p, params), eye, cfg)
    return out
