"""Orthonormal fast transforms used by SELL layers.

Implements the DCT-II / DCT-III (inverse) pair in three interchangeable ways:

* ``dct_matrix`` — the explicit ``N x N`` orthonormal DCT-II matrix (paper
  eq. 9).  Used as the oracle for tests and as the operand of the MXU
  matmul-DCT path (the TPU-native formulation, see DESIGN.md section 3).
* ``dct`` / ``idct`` — FFT-based O(N log N) transforms via Makhoul's (1980)
  even-permutation method, matching the paper's cuFFT "multiple call"
  implementation.  Pure ``jnp.fft``; differentiable.
* ``fwht`` — fast Walsh-Hadamard transform (for the Fastfood baseline).

All transforms operate on the LAST axis and are orthonormal, so
``idct(dct(x)) == x`` and ``dct_matrix(N) @ dct_matrix(N).T == I``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dct_matrix",
    "idct_matrix",
    "dct",
    "idct",
    "dct_via_matmul",
    "idct_via_matmul",
    "real_fft_matrix",
    "real_ifft_matrix",
    "real_fft",
    "real_ifft",
    "hadamard_matrix",
    "fwht",
    "make_riffle",
    "invert_permutation",
]


# ---------------------------------------------------------------------------
# Explicit DCT matrices (paper eq. 9, orthonormal convention).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _dct_matrix_np(n: int) -> np.ndarray:
    """Orthonormal DCT-II matrix as float64 numpy (cached host-side)."""
    k = np.arange(n)[None, :]          # frequency index
    m = np.arange(n)[None, :].T        # sample index
    mat = np.cos(np.pi * (2.0 * m + 1.0) * k / (2.0 * n))
    mat *= np.sqrt(2.0 / n)
    mat[:, 0] *= 1.0 / np.sqrt(2.0)    # eps_0 = 1/sqrt(2)
    return mat  # (n_in, n_freq): y = x @ mat  is the DCT-II of x


def dct_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal DCT-II matrix ``C`` with ``y = x @ C``; ``C^-1 = C.T``."""
    return jnp.asarray(_dct_matrix_np(n), dtype=dtype)


def idct_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Inverse (DCT-III) matrix, i.e. the transpose of :func:`dct_matrix`."""
    return jnp.asarray(_dct_matrix_np(n).T, dtype=dtype)


def dct_via_matmul(x: jax.Array, *, dtype=None) -> jax.Array:
    """DCT-II along the last axis via a dense matmul (MXU-native path)."""
    n = x.shape[-1]
    c = dct_matrix(n, dtype=dtype or x.dtype)
    return jnp.matmul(x, c)


def idct_via_matmul(x: jax.Array, *, dtype=None) -> jax.Array:
    n = x.shape[-1]
    c = idct_matrix(n, dtype=dtype or x.dtype)
    return jnp.matmul(x, c)


# ---------------------------------------------------------------------------
# FFT-based DCT (Makhoul 1980) — the O(N log N) path.
# ---------------------------------------------------------------------------

def _makhoul_permute(x: jax.Array) -> jax.Array:
    """v[n] = x[2n] for n < ceil(N/2); v[N-1-n] = x[2n+1]."""
    n = x.shape[-1]
    evens = x[..., 0::2]
    odds = x[..., 1::2]
    return jnp.concatenate([evens, jnp.flip(odds, axis=-1)], axis=-1)[..., :n]


def _makhoul_unpermute(v: jax.Array) -> jax.Array:
    n = v.shape[-1]
    half = (n + 1) // 2
    out = jnp.zeros_like(v)
    out = out.at[..., 0::2].set(v[..., :half])
    out = out.at[..., 1::2].set(jnp.flip(v[..., half:], axis=-1))
    return out


def dct(x: jax.Array) -> jax.Array:
    """Orthonormal DCT-II along the last axis, O(N log N) via rFFT.

    Matches ``x @ dct_matrix(N)`` to float tolerance.
    """
    n = x.shape[-1]
    in_dtype = x.dtype
    xf = x.astype(jnp.float32)
    v = _makhoul_permute(xf)
    vf = jnp.fft.fft(v.astype(jnp.complex64), axis=-1)[..., :n]
    k = jnp.arange(n, dtype=jnp.float32)
    # W = 2 * exp(-i pi k / 2N); taking the real part of W * V gives 2x the
    # unnormalized DCT-II.
    w = 2.0 * jnp.exp(-1j * jnp.pi * k / (2.0 * n)).astype(jnp.complex64)
    un = jnp.real(vf * w)  # un[k] = 2 * X[k] (unnormalized DCT-II)
    # Orthonormal scaling: Y[k] = sqrt(2/N) * eps_k * X[k], eps_0 = 1/sqrt(2).
    scale = jnp.full((n,), 0.5 * np.sqrt(2.0 / n), dtype=jnp.float32)
    scale = scale.at[0].set(0.5 * np.sqrt(1.0 / n))
    out = un * scale
    return out.astype(in_dtype)


def idct(y: jax.Array) -> jax.Array:
    """Orthonormal DCT-III (inverse of :func:`dct`) along the last axis."""
    n = y.shape[-1]
    in_dtype = y.dtype
    yf = y.astype(jnp.float32)
    # undo orthonormal scaling back to the un[k] = 2*X[k] spectrum
    scale = jnp.full((n,), 1.0 / (0.5 * np.sqrt(2.0 / n)), dtype=jnp.float32)
    scale = scale.at[0].set(1.0 / (0.5 * np.sqrt(1.0 / n)))
    un = yf * scale  # un[k] = 2 * sum_m v[m] cos(pi (2m+1) k / 2N) * ... real part spectrum
    k = jnp.arange(n, dtype=jnp.float32)
    w = jnp.exp(1j * jnp.pi * k / (2.0 * n)).astype(jnp.complex64)
    # Rebuild the length-N complex spectrum of v.  For a real v,
    # Vf[k] = 0.5 * w[k] * (un[k] - i*un_flip[k]) with un_flip[0] = 0.
    un_flip = jnp.concatenate(
        [jnp.zeros_like(un[..., :1]), jnp.flip(un[..., 1:], axis=-1)], axis=-1
    )
    vf = 0.5 * w * (un - 1j * un_flip)
    v = jnp.fft.ifft(vf.astype(jnp.complex64), axis=-1).real
    out = _makhoul_unpermute(v)
    return out.astype(in_dtype)


# ---------------------------------------------------------------------------
# Real FFT basis (the `circulant` family: A.F.D.F^-1 kept real).
#
# The complex DFT diagonalizes circulant matrices, but a complex transform
# would force complex diagonals and a complex MXU path.  Instead we use the
# real orthonormal trigonometric basis — the real 2x2-block form of the
# DFT: columns [dc, cos_1, sin_1, cos_2, sin_2, ..., (nyquist if n even)].
# Conjugating a pair-aligned diagonal by this basis spans exactly the
# rotation-scaled circulant algebra while every operand stays real, so the
# same Pallas kernels (which only need C real with C^-1 = C^T) apply.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _real_fft_matrix_np(n: int) -> np.ndarray:
    """Orthonormal real-DFT basis as float64 numpy (cached host-side)."""
    m = np.arange(n)[:, None].astype(np.float64)
    cols = [np.full((n, 1), 1.0 / np.sqrt(n))]
    for k in range(1, (n - 1) // 2 + 1):
        theta = 2.0 * np.pi * k * m / n
        cols.append(np.sqrt(2.0 / n) * np.cos(theta))
        cols.append(np.sqrt(2.0 / n) * np.sin(theta))
    if n % 2 == 0:
        cols.append(((-1.0) ** np.arange(n))[:, None] / np.sqrt(n))
    return np.concatenate(cols, axis=1)  # (n, n): y = x @ F


def real_fft_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal real-DFT basis ``F`` with ``y = x @ F``; ``F^-1 = F.T``."""
    return jnp.asarray(_real_fft_matrix_np(n), dtype=dtype)


def real_ifft_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`real_fft_matrix`, i.e. its transpose."""
    return jnp.asarray(_real_fft_matrix_np(n).T, dtype=dtype)


def real_fft(x: jax.Array) -> jax.Array:
    """Orthonormal real-DFT along the last axis, O(N log N) via rFFT.

    Matches ``x @ real_fft_matrix(N)`` to float tolerance.
    """
    n = x.shape[-1]
    in_dtype = x.dtype
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)  # (..., n//2 + 1)
    npair = (n - 1) // 2
    dc = xf[..., :1].real / np.sqrt(n)
    mid = xf[..., 1:1 + npair]
    # cos_k picks up Re X[k], sin_k picks up -Im X[k] (rfft convention
    # e^{-i theta}: X[k] = sum_m x_m (cos - i sin)).
    s = np.sqrt(2.0 / n)
    pairs = jnp.stack([s * mid.real, -s * mid.imag], axis=-1)
    pairs = pairs.reshape(*pairs.shape[:-2], 2 * npair)
    parts = [dc, pairs]
    if n % 2 == 0:
        parts.append(xf[..., -1:].real / np.sqrt(n))
    return jnp.concatenate(parts, axis=-1).astype(in_dtype)


def real_ifft(y: jax.Array) -> jax.Array:
    """Inverse of :func:`real_fft` (orthonormal, so the adjoint)."""
    n = y.shape[-1]
    in_dtype = y.dtype
    yf = y.astype(jnp.float32)
    npair = (n - 1) // 2
    # rebuild the one-sided complex spectrum of the "backward"-norm irfft:
    # X[0] = y_dc sqrt(n); X[k] = (y_cos - i y_sin) sqrt(n/2);
    # X[n/2] = y_nyq sqrt(n).
    dc = (yf[..., :1] * np.sqrt(n)).astype(jnp.complex64)
    pairs = yf[..., 1:1 + 2 * npair]
    pairs = pairs.reshape(*pairs.shape[:-1], npair, 2)
    mid = ((pairs[..., 0] - 1j * pairs[..., 1])
           * np.sqrt(n / 2.0)).astype(jnp.complex64)
    parts = [dc, mid]
    if n % 2 == 0:
        parts.append((yf[..., -1:] * np.sqrt(n)).astype(jnp.complex64))
    spec = jnp.concatenate(parts, axis=-1)
    return jnp.fft.irfft(spec, n=n, axis=-1).astype(in_dtype)


# ---------------------------------------------------------------------------
# Fast Walsh-Hadamard (the `hadamard` family / Fastfood baseline).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _hadamard_matrix_np(n: int) -> np.ndarray:
    """Normalized Sylvester-Hadamard matrix ``H/sqrt(n)`` (cached)."""
    if n < 1 or n & (n - 1):
        raise ValueError(f"Hadamard needs a power-of-two size, got {n}")
    h = np.ones((1, 1))
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h / np.sqrt(n)


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal Hadamard matrix; symmetric and involutive (H = H^-1)."""
    return jnp.asarray(_hadamard_matrix_np(n), dtype=dtype)


def fwht(x: jax.Array, *, normalize: bool = True) -> jax.Array:
    """Fast Walsh-Hadamard transform along the last axis (N must be 2^k)."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT needs a power-of-two size, got {n}")
    orig_shape = x.shape
    h = 1
    y = x
    while h < n:
        y = y.reshape(*orig_shape[:-1], n // (2 * h), 2, h)
        a = y[..., 0, :]
        b = y[..., 1, :]
        y = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    y = y.reshape(orig_shape)
    if normalize:
        y = y / jnp.sqrt(jnp.asarray(n, dtype=x.dtype))
    return y


# ---------------------------------------------------------------------------
# Permutations ("adjacent SELLs are incoherent", paper section 6.2).
# ---------------------------------------------------------------------------

def make_riffle(n: int) -> np.ndarray:
    """Perfect-shuffle (riffle) permutation indices for size ``n``.

    Deterministic, O(1) metadata to store (just the size).  Interleaves the
    two halves: [0, n/2, 1, n/2+1, ...].
    """
    half = (n + 1) // 2
    idx = np.empty((n,), dtype=np.int32)
    idx[0::2] = np.arange(half)
    idx[1::2] = np.arange(half, n)
    return idx


def invert_permutation(p: np.ndarray) -> np.ndarray:
    inv = np.empty_like(p)
    inv[p] = np.arange(len(p), dtype=p.dtype)
    return inv
