"""Deterministic, shardable synthetic data pipelines.

Offline container: no real corpora.  The pipeline is nevertheless built
like a production one — stateless index-based generation (any step's batch
is reproducible from (seed, step) alone), which makes data state trivially
checkpointable and elastic: a restarted job at step k on a different mesh
regenerates exactly the same global batch and reshards it.
"""

from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    SyntheticLM,
    make_batch_specs,
)
