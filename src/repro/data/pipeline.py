"""Synthetic LM data: Zipf-distributed token streams with Markov structure.

Generation is a pure function of (seed, step, example_index) so that:

* the iterator needs no mutable state — its "checkpoint" is the step
  counter already saved in the train state;
* any (data-parallel) shard can generate exactly its slice of the global
  batch — no host fan-out needed at 1000-node scale;
* restarts/elastic reshapes reproduce the identical batch sequence.

A Markov component makes the stream compressible, so a training LM shows a
real, monotonically decreasing loss (used by examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: bool = True       # mix in next-token structure
    frontend: Optional[str] = None  # "vision" | "audio" stub inputs
    n_frontend_tokens: int = 0
    d_model: int = 0                # frontend embedding width


class SyntheticLM:
    """Stateless synthetic LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        r_tok, r_mark, r_fe = jax.random.split(rng, 3)
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size

        # Zipf-ish marginal via exponential transform of uniform
        u = jax.random.uniform(r_tok, (b, s), minval=1e-6, maxval=1.0)
        ranks = jnp.floor((u ** (-1.0 / (cfg.zipf_a - 1.0)) - 1.0)).astype(jnp.int32)
        tokens = jnp.clip(ranks, 0, v - 1)

        if cfg.markov_order:
            # make ~half the tokens a deterministic function of the previous
            # token => learnable structure with known floor
            tu = tokens[:, :-1].astype(jnp.uint32)
            det = ((tu * jnp.uint32(2654435761) + jnp.uint32(12345))
                   % jnp.uint32(v)).astype(jnp.int32)
            coin = jax.random.bernoulli(r_mark, 0.5, (b, s - 1))
            nxt = jnp.where(coin, det, tokens[:, 1:])
            tokens = jnp.concatenate([tokens[:, :1], nxt], axis=1)

        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((b, 1), -1, jnp.int32)], axis=1)
        batch = {"tokens": tokens, "labels": labels}

        if cfg.frontend is not None and cfg.n_frontend_tokens > 0:
            fe = jax.random.normal(
                r_fe, (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
            batch["frontend_embeds"] = fe
            if cfg.frontend == "vision":
                # prefix positions carry image patches -> no LM loss there
                labels = batch["labels"]
                prefix = jnp.full((b, cfg.n_frontend_tokens), -1, jnp.int32)
                batch["labels"] = jnp.concatenate(
                    [prefix, labels[:, cfg.n_frontend_tokens:]], axis=1)
        return batch

    def shard_at(self, step: int, shard: int, n_shards: int) -> dict:
        """Generate only this host's slice of the global batch."""
        full = self.batch_at(step)  # cheap: synthetic; real impl slices I/O
        per = self.cfg.global_batch // n_shards
        return jax.tree.map(lambda x: x[shard * per:(shard + 1) * per], full)


def make_batch_specs(cfg: DataConfig, model_d: int = 0):
    """ShapeDtypeStructs for one global batch (dry-run input_specs)."""
    b, s = cfg.global_batch, cfg.seq_len
    spec = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.frontend is not None and cfg.n_frontend_tokens > 0:
        spec["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model or model_d), jnp.float32)
    return spec
