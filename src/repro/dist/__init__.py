"""Distributed execution subsystem.

Four modules wire the model zoo, optimizers, data pipeline and checkpoint
manager into a runnable sharded system (the launch/ scripts are thin CLIs
over these):

* :mod:`repro.dist.steps`       — train/serve step builders + state trees.
* :mod:`repro.dist.sharding`    — logical-axis rules -> PartitionSpecs for
                                  params, optimizer state, batches, caches.
* :mod:`repro.dist.elastic`     — mesh-shrink policy, straggler monitor,
                                  SIGTERM drain heartbeat.
* :mod:`repro.dist.compression` — int8 blockwise gradient quantization with
                                  error feedback and a compressed psum.

Everything here runs identically on the CPU container (1-device mesh) and a
pod — only the mesh shape changes.
"""

from repro.dist import compression, elastic, sharding, steps  # noqa: F401
