"""Gradient compression: blockwise int8 quantization + error feedback.

The data-parallel gradient all-reduce is the only traffic that crosses the
slow inter-pod links (see launch/mesh.py), so it is the one worth
compressing.  Scheme:

* **blockwise int8** — every ``BLOCK`` consecutive values share one fp32
  scale = max|x| / 127; the elementwise error is bounded by scale/2
  (tests/test_compression.py checks the bound as a property).
* **error feedback** — the quantization residual is carried to the next
  step and added before quantizing (Seide et al. 2014; Karimireddy et al.
  2019): the accumulated TRANSMITTED signal then tracks the true gradient
  sum to within one quantization step instead of drifting O(T).
* **compressed psum** — the shard_map-side helper: quantize (grad + error),
  all-reduce the dequantized values over the named axis, return the new
  local residual.  The int8 wire format of the collective itself is a
  transport concern (ROADMAP open item); the numerics — what every rank
  contributes and keeps — live here and are mesh-size-independent.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Flatten ``x`` and quantize in blocks of ``BLOCK``.

    Returns ``(q, scale)`` with ``q`` int8 of shape (n_blocks, BLOCK) (the
    tail block zero-padded) and ``scale`` fp32 of shape (n_blocks, 1).
    """
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    n_blocks = -(-n // BLOCK)
    pad = n_blocks * BLOCK - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n_blocks, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.where(scale > 0, blocks / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(q), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`quantize_int8` -> fp32 of shape (n,)."""
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:n]


def make_error_state(params) -> dict:
    """fp32 zero residuals, one per leaf (error-feedback carry)."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grad: jax.Array, error: jax.Array,
                    axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce of one leaf under ``shard_map``.

    Returns ``(summed_dequantized_grad, new_error)``; the caller carries
    ``new_error`` into the next step.  On a 1-member axis this reduces to
    (dequantize(quantize(g + e)), quantization residual) — the invariant
    ``ghat + new_e == g + e`` that test_compression pins down.
    """
    n = grad.size
    flat = grad.astype(jnp.float32).reshape(-1) + error.reshape(-1)
    # Drop non-finite contributions BEFORE quantizing: an inf/NaN leaf would
    # otherwise corrupt its block scale and — through the error-feedback
    # carry (new_error = flat - local) — poison every subsequent step with
    # no recovery.  Upstream grad-clip handles the magnitude; this handles
    # survival.
    flat = jnp.where(jnp.isfinite(flat), flat, 0.0)
    q, scale = quantize_int8(flat)
    local = dequantize_int8(q, scale, n)
    new_error = (flat - local).reshape(grad.shape)
    total = jax.lax.psum(local, axis_name)
    return total.reshape(grad.shape).astype(grad.dtype), new_error


def compressed_psum_tree(grads, errors, axis_name: str):
    """Leafwise :func:`compressed_psum` over a gradient pytree."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, new_e
