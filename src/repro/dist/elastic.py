"""Elastic execution utilities: mesh healing, straggler detection, drain.

Model-parallel groups are load-bearing (the weights are sharded across
them), so on device loss the policy shrinks DATA parallelism first —
dropping whole replicas — and only degrades the model axis when fewer
than one full model-parallel group survives.  Data-parallel size is kept a
power of two so gradient all-reduce rings stay balanced and the synthetic
data pipeline reshards evenly.
"""

from __future__ import annotations

import dataclasses
import signal
import threading
from typing import List, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

#: every StragglerMonitor flag (training step OR serving tick watchdog)
#: also lands in the process-global obs registry, so exporters see
#: straggler pressure without threading the monitor through them
_FLAGS = obs_metrics.REGISTRY.counter(
    "straggler_flags_total", "StragglerMonitor outlier flags")


def _pow2_floor(n: int) -> int:
    return 1 << (max(n, 1).bit_length() - 1)


@dataclasses.dataclass
class ElasticPolicy:
    """Resolve a (data, model) mesh shape from the surviving device count."""

    model_parallel: int = 16

    def resolve_mesh(self, n_devices: int) -> Tuple[int, int]:
        if n_devices < 1:
            raise ValueError("no devices")
        mp = self.model_parallel
        if n_devices >= mp:
            return (_pow2_floor(n_devices // mp), mp)
        # fewer chips than one model-parallel group: degrade the model axis
        return (1, _pow2_floor(n_devices))


class StragglerMonitor:
    """EWMA step-time monitor that flags outliers without absorbing them.

    An observation above ``factor`` x the EWMA is flagged and EXCLUDED from
    the average — a single preemption stall must not raise the baseline
    and mask the next one.  The first ``warmup`` observations always feed
    the EWMA (no baseline exists yet to judge them against).

    A SUSTAINED slowdown is not a straggler: after ``adapt_after``
    consecutive flags the monitor treats the new step time as a level
    shift, re-seeds the baseline from it and stops flagging — otherwise a
    legitimate workload change (longer sequence bucket, new data shard)
    would freeze the baseline and flag every step forever.

    Besides training steps, the serving engine reuses this as its
    tick-latency watchdog: straggling ticks are one of the pressure
    signals that drive the graceful-degradation ladder
    (:mod:`repro.serving.engine`), which calls :meth:`reset` on every
    ladder transition — the tick cost legitimately changes with the
    serving level, so the old baseline must not flag (or mask) the new
    one.
    """

    def __init__(self, alpha: float = 0.1, factor: float = 3.0,
                 warmup: int = 3, adapt_after: int = 5):
        self.alpha = alpha
        self.factor = factor
        self.warmup = warmup
        self.adapt_after = adapt_after
        self.ewma: Optional[float] = None
        self.flagged: List[int] = []
        self._count = 0
        self._consecutive = 0

    def reset(self) -> None:
        """Drop the baseline after a legitimate level shift (e.g. a
        serving degradation-ladder transition changed the per-tick cost);
        the next observation re-seeds the EWMA.  ``flagged`` history is
        kept — it is an audit log, not part of the baseline."""
        self.ewma = None
        self._count = 0
        self._consecutive = 0

    def observe(self, step: int, dt: float) -> bool:
        """Record one step time; True if ``step`` is a straggler."""
        self._count += 1
        if self.ewma is None:
            self.ewma = float(dt)
            return False
        if self._count > self.warmup and dt > self.factor * self.ewma:
            self._consecutive += 1
            if self._consecutive >= self.adapt_after:
                self.ewma = float(dt)  # level shift, not a straggler
                self._consecutive = 0
                return False
            self.flagged.append(step)
            _FLAGS.inc()
            obs_trace.instant_global("train", "straggler", step=step,
                                     dt_s=float(dt),
                                     ewma_s=float(self.ewma))
            return True
        self._consecutive = 0
        self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * float(dt)
        return False


class Heartbeat:
    """SIGTERM/SIGINT drain flag for the train loop.

    ``install()`` registers handlers and returns self; the loop polls
    ``should_stop`` once per step and checkpoints before exiting (the
    preemption path in launch/train.py).  Registration is skipped outside
    the main thread (signal handlers are main-thread-only in CPython).
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._signals = signals
        self._stop = threading.Event()
        self._previous = {}

    def install(self) -> "Heartbeat":
        try:
            for s in self._signals:
                self._previous[s] = signal.signal(s, self._handle)
        except ValueError:
            pass  # not the main thread
        return self

    def uninstall(self):
        for s, prev in self._previous.items():
            try:
                signal.signal(s, prev)
            except ValueError:
                pass
        self._previous = {}

    def _handle(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()
