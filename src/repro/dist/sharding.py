"""Logical-axis sharding rules for the model zoo.

One small engine resolves every placement decision in the system:

    spec_for(mesh, shape, logical) -> PartitionSpec

``logical`` names the TRAILING dims of ``shape`` (leading extra dims — the
stacked-layer axis under ``lax.scan`` — are never sharded: every device
runs every layer).  Each logical axis maps to an ordered tuple of mesh axes
(``RULES``); resolution applies three safeguards, in order:

* **presence** — rule axes missing from the mesh are dropped (the same
  rules serve the pod-less 2-axis host mesh and the 3-axis multi-pod mesh);
* **uniqueness** — a mesh axis is claimed at most once per array, first
  claim (leftmost logical dim) wins: expert weights claim "model" before
  the ffn dim can, and a sequence dim only takes "data" when the batch dim
  could not (batch=1 long-context decode);
* **divisibility** — the dim must divide evenly over the claimed axes,
  otherwise the dim falls back to replicated.

On top of the engine, :func:`param_specs` walks a model/optimizer state
tree and assigns logical axes by parameter role (path pattern):
embedding tables shard vocab over "model" and features over "data"
(ZeRO-3 flavour); attention/MLP/SSM projections shard (in, out) over
("data", "model") with output projections transposed; MoE expert stacks
claim "model" for the expert axis (expert parallelism); SELL diagonals are
O(N) — their last dim gets ZeRO-3 "data" sharding and everything else is
replicated; norms/biases/conv taps are replicated.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis candidates
RULES = {
    "batch": ("pod", "data"),
    "seq": ("pod", "data"),
    "vocab": ("model",),
    "embed": ("data",),
    "ffn": ("model",),
    "heads": ("model",),
    "expert": ("model",),
    "sell": ("data",),
}


def _axis_sizes(mesh) -> dict:
    return dict(mesh.shape)


def spec_for(mesh, shape: Sequence[int],
             logical: Sequence[Optional[str]]) -> P:
    """Resolve a PartitionSpec for ``shape`` under ``mesh``.

    ``logical`` covers the trailing ``len(logical)`` dims; leading dims are
    unsharded (stacked-layer convention).
    """
    sizes = _axis_sizes(mesh)
    lead = len(shape) - len(logical)
    if lead < 0:
        raise ValueError(f"logical {logical} longer than shape {shape}")
    assignment: list = [None] * len(shape)
    claimed: set = set()
    for i, name in enumerate(logical):
        if name is None:
            continue
        cand = tuple(a for a in RULES.get(name, ())
                     if a in sizes and a not in claimed)
        if not cand:
            continue
        total = math.prod(sizes[a] for a in cand)
        if total <= 0 or shape[lead + i] % total != 0:
            continue  # divisibility fallback: replicate this dim
        assignment[lead + i] = cand[0] if len(cand) == 1 else cand
        claimed.update(cand)
    return P(*assignment)


# ---------------------------------------------------------------------------
# Role resolution: param-tree path -> logical axes.
# ---------------------------------------------------------------------------

# projections whose weight is (in, out) with OUT being the model dim
_IN_PROJ = {"wq", "wk", "wv", "wg", "wu", "in_proj", "router"}
# projections whose weight is (in, out) with IN being the model dim
_OUT_PROJ = {"wo", "wd", "out_proj"}


def _path_str(path) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", None)))
        parts.append(str(key))
    return "/".join(parts)


def logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Trailing logical axes for one parameter leaf (by role pattern).

    Works on raw param trees and on optimizer-state trees (the "opt/m/..."
    prefix leaves the role suffix intact, so moments inherit their
    parameter's placement).
    """
    segs = path.split("/")
    name = segs[-1]
    parent = segs[-2] if len(segs) > 1 else ""
    if name == "table" and parent == "embed":
        return ("vocab", "embed")
    if "sell" in segs:
        # O(N) structured params: ZeRO-3 shard the feature dim over "data",
        # replicate the stacked (L, K) leading dims.
        return ("sell",) if ndim >= 1 else ()
    if ndim < 2:
        return ()  # scalars, norms, biases, conv taps: replicated
    if name in ("w", "u", "v") or parent in _IN_PROJ | _OUT_PROJ:
        expert = ("expert",) if "experts" in segs else ()
        if parent in _OUT_PROJ:
            trail = ("heads", "embed") if parent == "wo" else ("ffn", "embed")
        elif parent in ("wq", "wk", "wv"):
            trail = ("embed", "heads")
        else:
            trail = ("embed", "ffn")
        return expert + trail
    return ()


def param_specs(tree, mesh):
    """Same-structure tree of PartitionSpecs for a param/state tree.

    Accepts concrete arrays or ShapeDtypeStructs (``jax.eval_shape`` output).
    """
    def one(path, leaf):
        shape = leaf.shape
        return spec_for(mesh, shape, logical_axes_for(_path_str(path),
                                                      len(shape)))
    return jax.tree_util.tree_map_with_path(one, tree)


def param_shardings(tree, mesh):
    """NamedShardings for a param/state tree (jit in/out_shardings)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(tree, mesh))


# ---------------------------------------------------------------------------
# Batch and cache placement.
# ---------------------------------------------------------------------------

def data_specs(mesh, batch):
    """Batch leaves shard dim 0 over ("pod", "data"); the rest is local."""
    def one(leaf):
        nd = len(leaf.shape)
        return spec_for(mesh, leaf.shape, ("batch",) + (None,) * (nd - 1))
    return jax.tree.map(one, batch)


_KV_NAMES = {"k", "v", "xk", "xv", "attn_k", "attn_v"}


def cache_specs(cache, mesh):
    """Decode-cache placement: batch over "data", heads over "model".

    KV caches are (L, B, S, H, Dh); when the batch dim cannot shard
    (batch=1 long-context) the sequence dim takes the data shards instead
    — that falls out of the first-claim-wins engine, no special case.
    SSM states are (L, B, H, P, N) and conv windows (L, B, W-1, C).
    """
    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        nd = len(leaf.shape)
        if name in _KV_NAMES and nd == 5:
            logical = (None, "batch", "seq", "heads", None)
        elif name == "ssm" and nd == 5:
            logical = (None, "batch", "heads", None, None)
        else:
            logical = (None, "batch") + (None,) * max(nd - 2, 0)
            logical = logical[:nd]
        return spec_for(mesh, leaf.shape, logical)
    return jax.tree_util.tree_map_with_path(one, cache)
