"""Train/serve step builders and state trees for the launch stack.

The functions here are pure closures over (model, cfg, opt) so the
launchers can wrap them in ``jax.jit`` with explicit in/out shardings
(see :mod:`repro.dist.sharding`) and the dry-run can ``.lower()`` them
against ShapeDtypeStructs without allocating anything.

State layout (a plain dict pytree, checkpoint- and eval_shape-friendly)::

    {"params": <model params>, "opt": <optimizer state>, "step": int32[]}

SELL routing note: the step builders are transform-family agnostic.  The
``sell_kind`` / ``sell_method`` / ``sell_transform`` trio lives entirely
inside ``cfg`` (models/common.py) and is consumed by
``models.linear._sell_cfg`` at trace time — a family swap changes the
traced computation (which ``C`` matrices the kernels receive, which
autotune cache line feeds ``bm``) but not the state tree's structure, the
shardings, or anything this module builds.  The SELL param-group LR
multipliers in launch/train.py key on param-tree paths (``sell/a`` etc.),
which are also family-invariant.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist import compression
from repro.optim.optimizers import global_norm, tree_add


# ---------------------------------------------------------------------------
# State trees.
# ---------------------------------------------------------------------------

def init_state(model, cfg, opt, rng: jax.Array, compress_dp: int = 0) -> dict:
    """Concrete train state: params + optimizer moments + step counter.

    ``compress_dp > 0`` adds a ``grad_error`` tree — the per-data-rank int8
    quantization residuals (leading axis = data-parallel size) carried by
    the compressed gradient sync (:mod:`repro.dist.compression`).
    """
    params = model.init(rng, cfg)
    state = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress_dp > 0:
        state["grad_error"] = jax.tree.map(
            lambda p: jnp.zeros((compress_dp,) + p.shape, jnp.float32),
            params)
    return state


def abstract_state(model, cfg, opt, compress_dp: int = 0) -> dict:
    """ShapeDtypeStruct mirror of :func:`init_state` (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_state, model, cfg, opt,
                          compress_dp=compress_dp),
        jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Training.
# ---------------------------------------------------------------------------

def make_train_step(model, cfg, opt, accum_steps: int = 1,
                    compress_mesh=None, data_axis: str = "data") -> Callable:
    """Build ``step(state, batch) -> (new_state, metrics)``.

    ``accum_steps > 1`` splits the global batch into equal microbatches and
    accumulates loss/grads with a ``lax.scan`` (live memory is one
    microbatch's activations; the compiled program is O(1) in the number of
    microbatches).  With equal token counts per microbatch the mean loss
    and mean grads match the full-batch computation exactly, which
    tests/test_train_integration.py pins down.

    ``compress_mesh`` (a Mesh) routes the data-parallel gradient all-reduce
    through :func:`repro.dist.compression.compressed_psum_tree` under
    ``shard_map`` over ``data_axis``: int8 on the wire with error feedback.
    The state must then carry a ``grad_error`` tree (``init_state`` with
    ``compress_dp = mesh.shape[data_axis]``).  This path treats params as
    replicated across ``data_axis`` inside the shard_map body (pure data
    parallelism — the inter-pod DP sync is the traffic worth compressing);
    model-parallel placement still applies outside via jit shardings.

    With ``cfg.sell_method='pallas'`` the SELL projections' cascades
    differentiate through the fused cascade custom VJP, whose backward is
    the reverse-sweep Pallas kernel (``kernels/acdc_cascade_bwd``) — the
    train step's gradient pass moves O(N) HBM bytes per row regardless
    of cascade depth, matching the fused forward.  No step-builder
    plumbing is involved; ``jax.value_and_grad`` picks the VJP up here,
    which tests/test_kernel_grads.py pins with a routing assertion.
    """
    def loss_fn(params, batch):
        return model.loss_fn(params, batch, cfg)

    def grads_of(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            if b % accum_steps:
                raise ValueError(
                    f"global batch {b} not divisible by accum {accum_steps}")
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), grad_acc, g)
            return (loss_acc + l, grad_acc), None

        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def compressed_grads_of(params, batch, error):
        """Per-rank grads + error-feedback int8 psum under shard_map."""
        dsize = compress_mesh.shape[data_axis]

        def local_fn(params, batch, error):
            loss, grads = grads_of(params, batch)
            err = jax.tree.map(lambda e: e[0], error)       # drop rank axis
            grads, new_err = compression.compressed_psum_tree(
                grads, err, data_axis)
            grads = jax.tree.map(lambda g: g / dsize, grads)  # psum -> mean
            loss = jax.lax.pmean(loss, data_axis)
            return loss, grads, jax.tree.map(lambda e: e[None], new_err)

        rep = jax.tree.map(lambda _: P(), params)
        sharded = jax.tree.map(lambda _: P(data_axis), batch)
        err_spec = jax.tree.map(lambda _: P(data_axis), error)
        return shard_map(
            local_fn, mesh=compress_mesh,
            in_specs=(rep, sharded, err_spec),
            out_specs=(P(), rep, err_spec),
            check_rep=False,
        )(params, batch, error)

    def step(state, batch):
        if compress_mesh is not None:
            loss, grads, new_error = compressed_grads_of(
                state["params"], batch, state["grad_error"])
        else:
            loss, grads = grads_of(state["params"], batch)
            new_error = None
        updates, new_opt = opt.update(grads, state["opt"], state["params"],
                                      state["step"])
        new_params = tree_add(state["params"], updates)
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": global_norm(grads),
            "update_norm": global_norm(updates),
        }
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_error is not None:
            new_state["grad_error"] = new_error
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# Serving (single-token decode against the model-zoo caches).
# ---------------------------------------------------------------------------

def make_serve_step(model, cfg, sample: str = "greedy",
                    temperature: float = 1.0, top_k: int = 0,
                    top_p: float = 0.0, paged: bool = False) -> Callable:
    """Build ``step(params, cache, tokens, position, rng) -> (next, cache)``.

    One decode step against the family-specific cache (KV for attention
    archs, recurrent SSM/conv state for mamba-style archs, both for the
    hybrid) followed by on-device sampling: ``greedy`` argmax or ``temp``
    temperature-scaled categorical with optional top-k / top-p filtering
    (:mod:`repro.serving.sampler`).

    ``paged=True`` decodes against the paged block KV cache instead; the
    step signature gains the per-slot block tables:
    ``step(params, cache, tokens, position, block_tables, rng)``.  Inside
    the traced program, paged attention routes per ``ops.paged_attn_route``
    — the fused streaming kernel (``kernels/paged_attn.py``) on TPU when a
    block fits VMEM, the block-table gather otherwise — with identical
    greedy streams either way.
    """
    from repro.serving import sampler as sampler_mod  # avoid import cycle

    if sample not in ("greedy", "temp"):
        raise ValueError(f"unknown sampler {sample!r}")

    if paged:
        if model.decode_step_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged decode path")

        def step(params, cache, tokens, position, block_tables, rng):
            logits, new_cache = model.decode_step_paged(
                params, cache, tokens, position, block_tables, cfg)
            nxt = sampler_mod.sample(rng, logits, method=sample,
                                     temperature=temperature, top_k=top_k,
                                     top_p=top_p)
            return nxt, new_cache

        return step

    def step(params, cache, tokens, position, rng):
        logits, new_cache = model.decode_step(params, cache, tokens,
                                              position, cfg)
        nxt = sampler_mod.sample(rng, logits, method=sample,
                                 temperature=temperature, top_k=top_k,
                                 top_p=top_p)
        return nxt, new_cache

    return step


def make_insert_step() -> Callable:
    """jit'd slot insert: write a batch-1 slot cache into batch row
    ``slot`` of the full decode cache (donated — it is the dominant
    serving allocation and is replaced wholesale, so XLA updates the
    buffers in place).  Shared by the dense engine's admission path and
    the speculative draft's slot cache."""

    def insert(cache, slot_cache, slot):
        return jax.tree.map(
            lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                c, s.astype(c.dtype), slot, axis=1),
            cache, slot_cache)

    return jax.jit(insert, donate_argnums=(0,))


def make_verify_step(model, cfg, sample: str = "greedy",
                     temperature: float = 1.0, top_k: int = 0,
                     top_p: float = 0.0, paged: bool = False,
                     park: Optional[int] = None) -> Callable:
    """Build the speculative-decode verify step — ONE lowered program that
    appends k+1 tokens per slot, scores them, accepts, and commits.

    ``step(params, cache, tokens (B, k+1), drafts (B, k), draft_logits
    (B, k, V), position (B,)[, block_tables], rng) ->
    (accepted (B,), out_tokens (B, k+1), new_cache)``

    ``tokens`` is ``[pending, d_1 .. d_k]`` per row; the model's
    ``verify_step`` scores every position against the cache (a
    cache-extending, position-masked mini-prefill), acceptance is
    exact-match (greedy) or rejection sampling (temp,
    :mod:`repro.spec.verify`), and the cache is committed in-program:
    KV leaves keep their set-writes (rejected tail positions sit beyond
    the rewound frontier), recurrent SSM/conv leaves are re-selected at
    each row's accepted length from the per-position snapshots.
    ``out_tokens[:, :n+1]`` is the committed stream (accepted drafts plus
    the correction/bonus token at index n).

    ``park`` is the engine's parked-row position sentinel (rows at or
    beyond it — free or stalled slots — commit zero tokens); ``None``
    treats every row as advancing.

    ``paged=True`` verifies against the paged pool through the same
    attention dispatch as the decode step: the fused paged-attention
    kernel handles the k+1-query verify grid natively (one kernel body
    for both T=1 and T=k+1), so speculative serving streams pages without
    ever materialising the gathered virtual rows.
    """
    from repro.spec import verify as verify_mod  # avoid import cycle

    if sample not in ("greedy", "temp"):
        raise ValueError(f"unknown sampler {sample!r}")
    vfn = model.verify_step_paged if paged else model.verify_step
    if vfn is None:
        raise ValueError(
            f"family {cfg.family!r} has no "
            f"{'paged ' if paged else ''}speculative verify path")

    def _accept_commit(logits, states, cache, drafts, draft_logits,
                       position, rng):
        if sample == "greedy":
            n, nxt = verify_mod.greedy_accept(logits, drafts)
        else:
            n, nxt = verify_mod.rejection_accept(
                rng, logits, draft_logits, drafts, temperature=temperature,
                top_k=top_k, top_p=top_p)
        out = verify_mod.committed_tokens(drafts, n, nxt)
        if states is not None:
            advancing = (position < park) if park is not None else True
            n_adv = jnp.where(advancing, n + 1, 0).astype(jnp.int32)
            cache = verify_mod.commit_states(cache, states, n_adv)
        return n, out, cache

    if paged:
        def step(params, cache, tokens, drafts, draft_logits, position,
                 block_tables, rng):
            logits, new_cache, states = vfn(params, cache, tokens, position,
                                            block_tables, cfg)
            return _accept_commit(logits, states, new_cache, drafts,
                                  draft_logits, position, rng)

        return step

    def step(params, cache, tokens, drafts, draft_logits, position, rng):
        logits, new_cache, states = vfn(params, cache, tokens, position, cfg)
        return _accept_commit(logits, states, new_cache, drafts,
                              draft_logits, position, rng)

    return step


def make_prefill_step(model, cfg, full_logits: bool = False,
                      paged: bool = False) -> Callable:
    """Build ``step(params, cache, tokens, lengths[, fe]) -> (logits, cache)``.

    One lowered program runs the model over the whole (right-padded) prompt
    batch and scatters the resulting KV / SSM state into the decode cache —
    replacing ``prompt_len`` sequential decode dispatches with a single
    compiled prefill (the ROADMAP batched-prefill item).  ``lengths`` (B,)
    gives each row's real prompt length; cache slots at or beyond it are
    zeroed so the additive decode scatter stays sound when continuous
    batching reuses slots.

    Returns the logits at each row's last real token (B, V) by default, or
    the full (B, S, V) grid with ``full_logits=True`` (equivalence tests,
    dry-run lowering).

    ``paged=True`` builds the admission program for the paged engine
    instead: ``step(params, cache, template, tokens, lengths, phys_blocks,
    slot[, fe]) -> (last_logits, cache)``.  The batch-1 prefill runs into
    the dense ``template`` slab, whose KV is then page-scattered through
    ``phys_blocks`` (the slot's block-table row, unmapped entries already
    routed to the trash page) while batch-indexed leaves (encdec cross KV,
    zamba2 SSM/conv state) slot-insert at ``slot`` — prefill and the paged
    cache scatter stay ONE lowered program per admission.
    """
    if model.prefill is None:
        raise ValueError(f"family {cfg.family!r} has no prefill path")

    if paged:
        if model.init_cache_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged cache")
        from repro.models import attention as attn_mod

        def step(params, cache, template, tokens, lengths, phys_blocks,
                 slot, frontend_embeds=None):
            logits, slot_cache = model.prefill(params, template, tokens,
                                               cfg, lengths, frontend_embeds)
            new_cache = {}
            for key, leaf in cache.items():
                if key.endswith("_pages"):
                    slab = slot_cache[key[: -len("_pages")]]
                    new_cache[key] = attn_mod.scatter_prefill_pages(
                        leaf, slab, phys_blocks)
                else:
                    new_cache[key] = jax.lax.dynamic_update_slice_in_dim(
                        leaf, slot_cache[key].astype(leaf.dtype), slot,
                        axis=1)
            idx = jnp.maximum(lengths - 1, 0)
            last = jnp.take_along_axis(logits, idx[:, None, None],
                                       axis=1)[:, 0]
            return last, new_cache

        return step

    def step(params, cache, tokens, lengths, frontend_embeds=None):
        logits, new_cache = model.prefill(params, cache, tokens, cfg,
                                          lengths, frontend_embeds)
        if full_logits:
            return logits, new_cache
        idx = jnp.maximum(lengths - 1, 0)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
        return last, new_cache

    return step
