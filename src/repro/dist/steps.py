"""Train/serve step builders and state trees for the launch stack.

The functions here are pure closures over (model, cfg, opt) so the
launchers can wrap them in ``jax.jit`` with explicit in/out shardings
(see :mod:`repro.dist.sharding`) and the dry-run can ``.lower()`` them
against ShapeDtypeStructs without allocating anything.

State layout (a plain dict pytree, checkpoint- and eval_shape-friendly)::

    {"params": <model params>, "opt": <optimizer state>, "step": int32[]}
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import global_norm, tree_add


# ---------------------------------------------------------------------------
# State trees.
# ---------------------------------------------------------------------------

def init_state(model, cfg, opt, rng: jax.Array) -> dict:
    """Concrete train state: params + optimizer moments + step counter."""
    params = model.init(rng, cfg)
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(model, cfg, opt) -> dict:
    """ShapeDtypeStruct mirror of :func:`init_state` (no allocation)."""
    return jax.eval_shape(functools.partial(init_state, model, cfg, opt),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Training.
# ---------------------------------------------------------------------------

def make_train_step(model, cfg, opt, accum_steps: int = 1) -> Callable:
    """Build ``step(state, batch) -> (new_state, metrics)``.

    ``accum_steps > 1`` splits the global batch into equal microbatches and
    accumulates loss/grads with a ``lax.scan`` (live memory is one
    microbatch's activations; the compiled program is O(1) in the number of
    microbatches).  With equal token counts per microbatch the mean loss
    and mean grads match the full-batch computation exactly, which
    tests/test_train_integration.py pins down.
    """
    def loss_fn(params, batch):
        return model.loss_fn(params, batch, cfg)

    def grads_of(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            if b % accum_steps:
                raise ValueError(
                    f"global batch {b} not divisible by accum {accum_steps}")
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), grad_acc, g)
            return (loss_acc + l, grad_acc), None

        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        updates, new_opt = opt.update(grads, state["opt"], state["params"],
                                      state["step"])
        new_params = tree_add(state["params"], updates)
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": global_norm(grads),
            "update_norm": global_norm(updates),
        }
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# Serving (single-token decode against the model-zoo caches).
# ---------------------------------------------------------------------------

def make_serve_step(model, cfg, sample: str = "greedy",
                    temperature: float = 1.0) -> Callable:
    """Build ``step(params, cache, tokens, position, rng) -> (next, cache)``.

    One decode step against the family-specific cache (KV for attention
    archs, recurrent SSM/conv state for mamba-style archs, both for the
    hybrid) followed by sampling: ``greedy`` argmax or ``temp``
    temperature-scaled categorical draw from ``rng``.
    """
    if sample not in ("greedy", "temp"):
        raise ValueError(f"unknown sampler {sample!r}")

    def step(params, cache, tokens, position, rng):
        logits, new_cache = model.decode_step(params, cache, tokens,
                                              position, cfg)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(
                rng, logits.astype(jnp.float32) / max(temperature, 1e-6),
                axis=-1)
        return nxt.astype(jnp.int32), new_cache

    return step
