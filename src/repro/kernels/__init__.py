"""Pallas TPU kernels for the perf-critical ACDC hot path.

Layout (per repo convention):

* ``acdc_fused.py``         — single-call fused forward (8N bytes/row);
  also home of ``MAX_FUSED_N``, the VMEM gate shared by every fused path.
* ``acdc_bwd.py``           — fused per-layer backward (paper eqs. 10-14)
  in one kernel per row-block: recomputes ``h2`` in VMEM (section 5.3
  trade), emits the dx tile, accumulates da/dd/dbias in fp32 VMEM scratch
  across the row grid.  Two-call degradation for N > ``MAX_FUSED_N``.
* ``acdc_cascade_fused.py`` — order-K cascade forward in ONE kernel: the
  activation row-block stays in VMEM across all K layers (8N bytes/row
  independent of K, vs 8KN for the per-layer scan), with interleaved ReLU
  fused on the VPU and the riffle permutation folded into the columns of
  the mid-cascade C^T (no in-kernel gathers).  ``fits_vmem`` documents
  and enforces the budget: (2-3) N^2 transform matrices + K stacked
  diagonals + row tiles.
* ``acdc_cascade_bwd.py``   — order-K REVERSE-SWEEP backward in ONE
  kernel: forward re-walk of the x tile stashes the K-1 layer inputs in
  VMEM scratch, then the eqs. 10-14 sweep runs layer K-1..0 with the
  cotangent block resident — 12N HBM bytes/row independent of K.  Its
  VMEM budget includes the (K-1, bm, N) stash, so the row block shrinks
  with depth and ``ops.py`` falls back to the per-layer scan when no
  block fits.
* ``paged_attn.py``         — fused paged-attention decode/verify kernel
  for the serving engine: walks each slot's block table from SMEM,
  DMA-streams only the mapped in-frontier K/V pages chunk-by-chunk
  through double-width VMEM scratch, runs online-softmax per chunk with
  the causal/window mask derived from ``position``, and scatters the new
  token's K/V into the tail page in the same program (pool aliased
  in-place).  One body serves both grids: decode (T=1) and speculative
  verify (T=k+1).  The ``(B, virtual, Hkv, Dh)`` gather view is never
  materialised.
* ``scaled_matmul.py``      — blocked (m,n,k) scaled matmul kernel; the
  building block of every > ``MAX_FUSED_N`` regime.
* ``autotune.py``           — first-call on-device row-block sweep
  ({64, 128, 256}, memoized per (N, K, dtype, direction) and persisted
  to ``results/autotune_cache.json`` for device runs) feeding ``bm`` to
  the fused fwd/bwd/cascade/cascade_bwd kernels; returns the old fixed
  constants off-device so CPU/CI runs are unchanged.
* ``ops.py``                — jit'd public wrappers + custom VJPs:
  per-layer ``acdc_fused``/``acdc_fused_nobias`` (fused Pallas backward)
  and cascade-level ``acdc_cascade_op`` (whole-cascade forward fusion,
  reverse-sweep backward, per-layer-scan fallback; routing counted in
  ``CASCADE_BWD_DISPATCHES``).
* ``ref.py``                — pure-jnp oracles the tests assert against,
  including the four-matmul backward formulation the fused kernel
  replaced.

Backward memory model, per row of an order-K cascade (the trajectory
BENCH_kernels.json tracks; N fp32 features, transform matrices excluded
as batch-amortized)::

    four XLA matmuls / layer     48N * K   gc, h2, dh1 each round-trip HBM
    fused per-layer kernel       12N * K   x, g in, dx out — per layer,
      (+ scan remat)           + 8N*(K-1)  layer inputs written+read back
    reverse-sweep kernel         12N       x, g in, dx out ONCE; stash
                                           and cotangent live in VMEM,
                                           independent of K

The forward trajectory is the analogous 48N -> 8N*K -> 8N (whole-cascade
fusion).  Together they put the full training step, not just inference,
at the paper's section 5 roofline.

Serving-side attention memory model, per slot per layer per tick (the
trajectory BENCH_serve.json tracks; MB = pages per slot row, B = tokens
per page, len = the slot's live length)::

    block-table gather     MB * B * Hkv * Dh * 2 * itemsize   the whole
                           virtual row, K and V, regardless of fill
    fused streaming        ceil(len / B) * B * Hkv * Dh * 2 * itemsize
                           only mapped in-frontier pages; parked and
                           stalled rows cost zero

i.e. gather traffic is O(max_len) per slot while the kernel's is O(len)
— independent of how generously the page table is provisioned.  Routing
lives in ``ops.paged_attn_route`` (counted in ``PAGED_ATTN_DISPATCHES``):
fused on TPU (or when forced via ``REPRO_PAGED_ATTN=fused``) when an
autotuned ``(page_chunk, head_block)`` fits the per-chunk VMEM budget,
gather otherwise.

Transform-family support matrix (``core/families.py``): the kernel
bodies take ``C``/``C^T`` (and the riffle-folded ``C^T[:, perm]``) as
operands, so every real-orthonormal family runs the SAME kernels — the
family only changes which matrices ``ops.py`` feeds them and which key
the autotuner sweeps under::

    family      fused fwd   fused bwd   cascade fwd   cascade bwd   notes
    acdc        yes         yes         yes           yes           DCT-II
    circulant   yes         yes         yes           yes           real-DFT
    hadamard    yes         yes         yes           yes           pow2 N

``autotune.py`` keys its memo/persistent cache on
``(direction, n, k, dtype, bias, permute, family)`` so a block size
swept for one family's matrix pair is never reused for another's
(pre-family 6-field cache entries are migrated on load as ``acdc``).
A family with ``complex_diagonals=True`` would NOT get the fused paths
(the kernels are real-only); all registered families are real.
"""
