"""Pallas TPU kernels for the perf-critical ACDC hot path.

Layout (per repo convention):

* ``acdc_fused.py``   — single-call fused kernel (pl.pallas_call + BlockSpec)
* ``scaled_matmul.py``— blocked (m,n,k) scaled matmul kernel
* ``ops.py``          — jit'd public wrappers + custom VJP (recompute bwd)
* ``ref.py``          — pure-jnp oracles the tests assert against
"""
