"""Pallas TPU kernels for the perf-critical ACDC hot path.

Layout (per repo convention):

* ``acdc_fused.py``         — single-call fused forward (8N bytes/row);
  also home of ``MAX_FUSED_N``, the VMEM gate shared by every fused path.
* ``acdc_bwd.py``           — fused backward (paper eqs. 10-14) in one
  kernel per row-block: recomputes ``h2`` in VMEM (section 5.3 trade),
  emits the dx tile, accumulates da/dd/dbias in fp32 VMEM scratch across
  the row grid.  Two-call degradation for N > ``MAX_FUSED_N``.
* ``acdc_cascade_fused.py`` — order-K cascade forward in ONE kernel: the
  activation row-block stays in VMEM across all K layers (8N bytes/row
  independent of K, vs 8KN for the per-layer scan), with interleaved ReLU
  fused on the VPU and the riffle permutation folded into the columns of
  the mid-cascade C^T (no in-kernel gathers).  ``fits_vmem`` documents
  and enforces the budget: (2-3) N^2 transform matrices + K stacked
  diagonals + row tiles.
* ``scaled_matmul.py``      — blocked (m,n,k) scaled matmul kernel; the
  building block of every > ``MAX_FUSED_N`` regime.
* ``autotune.py``           — first-call on-device row-block sweep
  ({64, 128, 256}, memoized per (N, K, dtype, direction)) feeding ``bm``
  to the fused forward/backward/cascade kernels; returns the old fixed
  constants off-device so CPU/CI runs are unchanged.
* ``ops.py``                — jit'd public wrappers + custom VJPs:
  per-layer ``acdc_fused``/``acdc_fused_nobias`` (fused Pallas backward)
  and cascade-level ``acdc_cascade_op`` (whole-cascade forward fusion,
  recompute backward over per-layer fused kernels).
* ``ref.py``                — pure-jnp oracles the tests assert against,
  including the four-matmul backward formulation the fused kernel
  replaced.
"""
