"""Fused ACDC backward Pallas kernel — eqs. (10)-(14) in one pass.

The forward kernel (``acdc_fused.py``) moves 8N bytes of HBM traffic per
row.  Before this kernel existed, the custom VJP lowered the backward to
four separate XLA fp32 matmuls with ``gc``, ``h2`` and ``dh1`` each
round-tripping HBM — 3 extra (M, N) fp32 tensors of traffic per layer.
Here the whole backward runs per row-block with every intermediate in
VMEM, matching the forward's memory behaviour:

    HBM reads : x tile + g tile (+ C / C^T, amortized over the grid)
    VMEM      : gc = g C,  h2 = (x*a) C   (RECOMPUTED — paper section 5.3
                memory/runtime trade: h2 is never stored by the forward),
                dh1 = (gc * d) C^T
    HBM write : dx tile; da / dd / dbias once, at the last grid step

The diagonal gradients are full-batch reductions (paper eqs. 10-12)::

    dL/dbias = sum_rows gc
    dL/dd    = sum_rows h2 * gc
    dL/da    = sum_rows x * dh1
    dL/dx    = a * dh1

so they are accumulated across the row grid in fp32 VMEM scratch (TPU
grids execute sequentially; same pattern as the k-loop accumulator in
``scaled_matmul.py``) and written out on the final grid step.  Zero-padded
rows of x and g contribute exact zeros to every partial sum, so padding M
up to the block size is free.

For N > ``MAX_FUSED_N`` (C and C^T no longer fit VMEM together with the
row tiles) :func:`acdc_bwd_two_call` mirrors the forward's two-call
regime: the three transform matmuls run as ``scaled_matmul`` Pallas
kernels with the diagonal scalings fused into the k-loop, and only the
unavoidable (M, N) intermediates ``gc``/``dh1`` round-trip HBM.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import scaled_matmul as smm_mod

# The backward keeps more live VMEM than the forward (x, g, dx tiles plus
# gc/h2/dh1 intermediates next to the two N^2 transform matrices), so its
# default row block is half the forward's.
DEFAULT_BM = 128


def _acdc_bwd_kernel(nm, with_bias, x_ref, g_ref, a_ref, d_ref,
                     c_ref, ct_ref, *rest):
    """One row-block of the fused backward; diagonal grads accumulate.

    ``with_bias`` statically drops the dbias reduction, its scratch and
    its output for the bias-free primitive (the LM path) — the same (M, N)
    reduction ``acdc_fused_nobias`` exists to avoid in the forward.
    """
    if with_bias:
        dx_ref, da_ref, dd_ref, db_ref, da_acc, dd_acc, db_acc = rest
    else:
        dx_ref, da_ref, dd_ref, da_acc, dd_acc = rest
        db_ref = db_acc = None
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        da_acc[...] = jnp.zeros_like(da_acc)
        dd_acc[...] = jnp.zeros_like(dd_acc)
        if db_acc is not None:
            db_acc[...] = jnp.zeros_like(db_acc)

    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    ct = ct_ref[...].astype(jnp.float32)

    gc = jnp.dot(g, c, preferred_element_type=jnp.float32)
    h2 = jnp.dot(x * a, c, preferred_element_type=jnp.float32)  # recompute
    dd_acc[...] += jnp.sum(h2 * gc, axis=0, keepdims=True)
    if db_acc is not None:
        db_acc[...] += jnp.sum(gc, axis=0, keepdims=True)
    dh1 = jnp.dot(gc * d, ct, preferred_element_type=jnp.float32)
    da_acc[...] += jnp.sum(x * dh1, axis=0, keepdims=True)
    dx_ref[...] = (a * dh1).astype(dx_ref.dtype)

    @pl.when(i == nm - 1)
    def _finalize():
        da_ref[...] = da_acc[...]
        dd_ref[...] = dd_acc[...]
        if db_ref is not None:
            db_ref[...] = db_acc[...]


@functools.partial(jax.jit,
                   static_argnames=("with_bias", "bm", "interpret"))
def acdc_bwd_pallas(
    x: jax.Array,
    g: jax.Array,
    a: jax.Array,
    d: jax.Array,
    c: jax.Array,
    ct: jax.Array,
    *,
    with_bias: bool = True,
    bm: int = DEFAULT_BM,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array]]:
    """Fused backward over 2-D ``x``/``g`` of shape (M, N).

    Returns ``(dx, da, dd, dbias)`` with ``dx`` in ``x.dtype`` and the
    diagonal gradients in fp32 (full-batch reductions stay in the
    accumulator precision; callers cast to the parameter dtype).
    ``with_bias=False`` skips the dbias reduction and returns ``None``
    in its place.
    """
    m, n = x.shape
    bm = min(bm, max(8, m))
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
        g = jnp.pad(g, ((0, pad_m), (0, 0)))
    nm = x.shape[0] // bm
    grid = (nm,)

    a2 = a.reshape(1, n)
    d2 = d.reshape(1, n)

    diag_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    mat_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    row_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))

    n_diag_outs = 3 if with_bias else 2
    diag_out = jax.ShapeDtypeStruct((1, n), jnp.float32)
    outs = pl.pallas_call(
        functools.partial(_acdc_bwd_kernel, nm, with_bias),
        grid=grid,
        in_specs=[row_spec, row_spec, diag_spec, diag_spec,
                  mat_spec, mat_spec],
        out_specs=[row_spec] + [diag_spec] * n_diag_outs,
        out_shape=[jax.ShapeDtypeStruct((x.shape[0], n), x.dtype)]
        + [diag_out] * n_diag_outs,
        scratch_shapes=[pltpu.VMEM((1, n), jnp.float32)] * n_diag_outs,
        interpret=interpret,
    )(x, g, a2, d2, c, ct)
    dx, da, dd = outs[0], outs[1], outs[2]
    db = outs[3].reshape(n) if with_bias else None
    if pad_m:
        dx = dx[:m]
    return dx, da.reshape(n), dd.reshape(n), db


def acdc_bwd_two_call(
    x: jax.Array,
    g: jax.Array,
    a: jax.Array,
    d: jax.Array,
    c: jax.Array,
    ct: jax.Array,
    *,
    with_bias: bool = True,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array]]:
    """Backward for the N > MAX_FUSED_N regime via chained scaled matmuls.

    ``gc`` and ``dh1`` land in HBM exactly once each (unavoidable at sizes
    where the transform matrix no longer fits VMEM); the diagonal scalings
    ride the matmul k-loops for free and the remaining reductions are
    single-pass element-wise XLA ops.
    """
    xf = x.astype(jnp.float32)
    gc = smm_mod.scaled_matmul_pallas(g.astype(jnp.float32), c,
                                      interpret=interpret)
    h2 = smm_mod.scaled_matmul_pallas(xf, c, pre=a.astype(jnp.float32),
                                      interpret=interpret)
    dd = jnp.sum(h2 * gc, axis=0)
    db = jnp.sum(gc, axis=0) if with_bias else None
    dh1 = smm_mod.scaled_matmul_pallas(gc, ct, pre=d.astype(jnp.float32),
                                       interpret=interpret)
    da = jnp.sum(xf * dh1, axis=0)
    dx = (a.astype(jnp.float32) * dh1).astype(x.dtype)
    return dx, da, dd, db
