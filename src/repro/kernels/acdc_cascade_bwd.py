"""Reverse-sweep fused cascade backward — O(1)-in-K HBM bytes per row.

The cascade-level custom VJP used to rematerialize every layer input to
HBM (``lax.scan`` forward re-walk) and then run K fused per-layer
backward kernels in reverse: 12N bytes/row per layer plus the remat
round trips, i.e. O(KN) total — BENCH_kernels.json showed the backward
wall clock growing linearly in K while the fused forward stayed flat.

This kernel walks all K stacked layers in reverse in ONE Pallas call,
per row-block:

1. **Forward re-walk in VMEM** — the x tile is read from HBM once and
   pushed through the K-1 interleaved layers exactly as the fused
   forward does (fp32 resident activation, ReLU on the VPU, riffle
   folded into the mid-cascade ``C^T`` columns).  Each layer input
   ``h_i`` is stashed in a ``(K-1, bm, N)`` VMEM scratch — recomputation
   replaces the HBM remat (the paper's section 5.3 memory/runtime trade
   applied at cascade scope).
2. **Reverse sweep with the cotangent resident** — the g tile is read
   once and the eqs. (10)-(14) backward runs layer K-1 .. 0 with the
   cotangent block never leaving VMEM.  Per-layer dA/dD/dbias partial
   sums accumulate in fp32 ``(K, N)`` VMEM scratch across the row grid
   and are written once, at the last grid step.

Interleaving transposes are folded into the transform operands so no
in-kernel gather is ever issued:

* forward re-walk: ``relu(z)[:, p] == relu(z @ C^T[:, p])`` — same
  column-permuted ``ct_mid`` as the fused forward;
* ReLU mask: ``h_{i+1} = relu(z_i)[:, p]`` is the stashed NEXT layer
  input, and ``(z_i > 0)[:, p] == (h_{i+1} > 0)`` — so the mask applies
  elementwise in h-space against the stash, before un-permuting;
* reverse un-permute: ``w[:, p^-1] @ C == w @ C[p, :] == w @ ct_mid^T``
  — a ``dot_general`` contraction against ``ct_mid``'s second axis, no
  fourth matrix in VMEM.

HBM traffic per row: read x + read g + write dx = 12N bytes,
INDEPENDENT of K — symmetric with the fused forward's 8N.  The price is
the stash: VMEM grows by ``4 (K-1) bm N`` bytes, so :func:`pick_bm`
shrinks the row block with depth and ``ops.py`` falls back to the
per-layer scan path when no block size fits (the forward can stay fused
while the backward falls back — the budgets differ).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.acdc_cascade_fused import VMEM_BUDGET
from repro.kernels.acdc_fused import MAX_FUSED_N

DEFAULT_BM = 128

#: candidate row blocks, largest first; smaller than the forward's floor
#: because the stash eats VMEM linearly in K.
CANDIDATE_BMS = (256, 128, 64, 32, 16)


def cascade_bwd_vmem_bytes(n: int, k: int, *, permute: bool, bias: bool,
                           bm: int = DEFAULT_BM) -> int:
    """Estimated live VMEM of the reverse-sweep backward (see module doc)."""
    mats = 3 if permute else 2          # C, C^T (+ column-permuted C^T)
    diags = 3 if bias else 2            # stacked a, d (+ bias)
    accs = 2 * diags                    # (K, N) grad accumulators + outputs
    stash = (k - 1) * bm * n            # recomputed layer inputs
    tiles = 7 * bm * n                  # x, g, dx + gc/h2/dh1/h live fp32
    return 4 * (mats * n * n + (diags + accs) * k * n + stash + tiles)


def pick_bm(n: int, k: int, *, permute: bool, bias: bool) -> Optional[int]:
    """Largest row block that keeps the reverse sweep inside the VMEM
    budget, or ``None`` if even the smallest tile doesn't fit."""
    if n > MAX_FUSED_N or k < 2:
        return None
    for bm in CANDIDATE_BMS:
        if cascade_bwd_vmem_bytes(n, k, permute=permute, bias=bias,
                                  bm=bm) <= VMEM_BUDGET:
            return bm
    return None


def fits_vmem(n: int, k: int, *, permute: bool, bias: bool) -> bool:
    """Whether the order-K reverse-sweep backward fits the VMEM budget."""
    return pick_bm(n, k, permute=permute, bias=bias) is not None


def _unpermute_matmul(w, ct_mid):
    """``w[:, p^-1] @ C`` without a gather: contract against ``ct_mid``'s
    second axis (``ct_mid = C^T[:, p]`` so ``ct_mid^T = C[p, :]``)."""
    return jax.lax.dot_general(
        w, ct_mid, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _cascade_bwd_kernel(k, nm, relu, has_bias, has_mid, *refs):
    """One row-block: forward re-walk (stash) + reverse sweep, all VMEM."""
    it = iter(refs)
    x_ref, g_ref, a_ref, d_ref = (next(it) for _ in range(4))
    bias_ref = next(it) if has_bias else None
    c_ref, ct_ref = next(it), next(it)
    ct_mid_ref = next(it) if has_mid else None
    dx_ref, da_ref, dd_ref = next(it), next(it), next(it)
    db_ref = next(it) if has_bias else None
    stash = next(it)
    da_acc, dd_acc = next(it), next(it)
    db_acc = next(it) if has_bias else None

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        da_acc[...] = jnp.zeros_like(da_acc)
        dd_acc[...] = jnp.zeros_like(dd_acc)
        if db_acc is not None:
            db_acc[...] = jnp.zeros_like(db_acc)

    c = c_ref[...].astype(jnp.float32)
    ct = ct_ref[...].astype(jnp.float32)
    ct_mid = ct_mid_ref[...].astype(jnp.float32) if has_mid else ct
    x = x_ref[...].astype(jnp.float32)

    # ---- forward re-walk: stash h_1 .. h_{K-1} (h_0 == x tile). --------
    h = x
    for li in range(k - 1):  # K static: unrolled, stash indexed statically
        h1 = h * a_ref[li:li + 1, :].astype(jnp.float32)
        h2 = jnp.dot(h1, c, preferred_element_type=jnp.float32)
        h3 = h2 * d_ref[li:li + 1, :].astype(jnp.float32)
        if bias_ref is not None:
            h3 = h3 + bias_ref[li:li + 1, :].astype(jnp.float32)
        h = jnp.dot(h3, ct_mid, preferred_element_type=jnp.float32)
        if relu:
            h = jnp.maximum(h, 0.0)
        stash[li] = h

    # ---- reverse sweep: cotangent stays resident. ----------------------
    gcur = g_ref[...].astype(jnp.float32)
    for li in range(k - 1, -1, -1):
        h_i = stash[li - 1] if li > 0 else x
        if li == k - 1:
            gc = jnp.dot(gcur, c, preferred_element_type=jnp.float32)
        else:
            # interleave backward: mask in h-space against the stashed
            # NEXT input, un-permute folded into the transform.
            if relu:
                gcur = jnp.where(stash[li] > 0.0, gcur, 0.0)
            if has_mid:
                gc = _unpermute_matmul(gcur, ct_mid_ref[...].astype(
                    jnp.float32))
            else:
                gc = jnp.dot(gcur, c, preferred_element_type=jnp.float32)
        if db_acc is not None:
            db_acc[li:li + 1, :] += jnp.sum(gc, axis=0, keepdims=True)
        h2 = jnp.dot(h_i * a_ref[li:li + 1, :].astype(jnp.float32), c,
                     preferred_element_type=jnp.float32)
        dd_acc[li:li + 1, :] += jnp.sum(h2 * gc, axis=0, keepdims=True)
        dh1 = jnp.dot(gc * d_ref[li:li + 1, :].astype(jnp.float32), ct,
                      preferred_element_type=jnp.float32)
        da_acc[li:li + 1, :] += jnp.sum(h_i * dh1, axis=0, keepdims=True)
        gcur = a_ref[li:li + 1, :].astype(jnp.float32) * dh1

    dx_ref[...] = gcur.astype(dx_ref.dtype)

    @pl.when(i == nm - 1)
    def _finalize():
        da_ref[...] = da_acc[...]
        dd_ref[...] = dd_acc[...]
        if db_ref is not None:
            db_ref[...] = db_acc[...]


@functools.partial(jax.jit,
                   static_argnames=("relu", "bm", "interpret"))
def acdc_cascade_bwd_pallas(
    x: jax.Array,
    g: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array],
    c: jax.Array,
    ct: jax.Array,
    ct_mid: Optional[jax.Array],
    *,
    relu: bool = False,
    bm: int = DEFAULT_BM,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array]]:
    """Reverse-sweep backward over 2-D ``x``/``g`` of shape (M, N).

    ``a``/``d``/``bias`` are the stacked (K, N) per-layer diagonals;
    ``ct_mid`` the column-permuted inverse transform of the riffled
    forward (``None`` when not riffling).  Returns ``(dx, da, dd, db)``
    with ``dx`` in ``x.dtype`` and the (K, N) diagonal grads in fp32
    (accumulator precision; callers cast); ``db`` is ``None`` when
    ``bias`` is.  Zero-padded g rows contribute exact zeros to every
    reduction, so ragged M is padded internally for free.
    """
    m, n = x.shape
    k = a.shape[0]
    if k < 2:
        raise ValueError("reverse-sweep backward needs K >= 2 "
                         f"(got K={k}); K=1 uses the per-layer kernel")
    bm = min(bm, max(8, m))
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
        g = jnp.pad(g, ((0, pad_m), (0, 0)))
    nm = x.shape[0] // bm
    grid = (nm,)

    stack_spec = pl.BlockSpec((k, n), lambda i: (0, 0))
    mat_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    row_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))

    operands = [x, g, a, d]
    in_specs = [row_spec, row_spec, stack_spec, stack_spec]
    if bias is not None:
        operands.append(bias)
        in_specs.append(stack_spec)
    operands += [c, ct]
    in_specs += [mat_spec, mat_spec]
    if ct_mid is not None:
        operands.append(ct_mid)
        in_specs.append(mat_spec)

    n_diag_outs = 3 if bias is not None else 2
    stack_out = jax.ShapeDtypeStruct((k, n), jnp.float32)
    scratch = [pltpu.VMEM((k - 1, bm, n), jnp.float32)]
    scratch += [pltpu.VMEM((k, n), jnp.float32)] * n_diag_outs

    kernel = functools.partial(_cascade_bwd_kernel, k, nm, relu,
                               bias is not None, ct_mid is not None)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[row_spec] + [stack_spec] * n_diag_outs,
        out_shape=[jax.ShapeDtypeStruct((x.shape[0], n), x.dtype)]
        + [stack_out] * n_diag_outs,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    dx, da, dd = outs[0], outs[1], outs[2]
    db = outs[3] if bias is not None else None
    if pad_m:
        dx = dx[:m]
    return dx, da, dd, db
