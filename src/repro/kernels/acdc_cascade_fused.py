"""Whole-cascade fused ACDC forward — 8N bytes/row independent of K.

``acdc_cascade`` used to scan over K per-layer kernel calls, so an
order-K SELL paid K full HBM round trips for the activation (8KN bytes
per row).  This kernel loops over the stacked (K, N) diagonals INSIDE the
kernel, keeping the activation row-block in VMEM between layers: the row
is read from HBM once, transformed K times on-chip, and written once —
the paper's section 5 "minimum bytes moved" argument extended from one
layer to the whole cascade.

Interleavings (the CaffeNet configuration of section 6.2) are fused too:

* ReLU between layers is a VPU ``maximum`` on the resident block;
* the riffle permutation is FOLDED INTO THE INVERSE TRANSFORM — for a
  permutation ``p``, ``(z @ C^T)[:, p] == z @ C^T[:, p]``, so mid-cascade
  layers multiply by a column-permuted ``C^T`` and no in-kernel gather is
  ever issued (gathers along the lane axis are VPU-hostile on TPU).

VMEM budget (the gate for using this kernel, see :func:`fits_vmem`)::

    transform matrices : C, C^T fp32 (+ permuted C^T when riffling)
                         -> (2 or 3) * 4 N^2 bytes
    stacked diagonals  : a, d (+ bias) -> (2 or 3) * 4 K N bytes
    activation tiles   : x block, y block + two live fp32 intermediates
                         -> ~4 * 4 bm N bytes

The matrices dominate: ~8 MB at N = 1024 (== MAX_FUSED_N), ~12 MB when
riffling adds the third.  The row block shrinks to compensate —
:func:`pick_bm` chooses the largest ``bm`` that keeps the total inside
the budget (riffled N = 1024 fuses at bm = 64; unriffled keeps 256) and
``ops.py`` falls back to the per-layer scan only when no block size
fits.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.acdc_fused import MAX_FUSED_N

DEFAULT_BM = 256

# Conservative per-core VMEM budget for fits_vmem (bytes).  Real cores
# have ~16 MB; leave headroom for double-buffered pipelining.
VMEM_BUDGET = 14 * 1024 * 1024


def cascade_vmem_bytes(n: int, k: int, *, permute: bool, bias: bool,
                       bm: int = DEFAULT_BM) -> int:
    """Estimated live VMEM of the fused cascade kernel (see module doc)."""
    mats = 3 if permute else 2
    diags = 3 if bias else 2
    tiles = 4  # x block, y block, two fp32 intermediates
    return 4 * (mats * n * n + diags * k * n + tiles * bm * n)


def pick_bm(n: int, k: int, *, permute: bool, bias: bool) -> Optional[int]:
    """Largest row block that keeps the fused cascade inside the VMEM
    budget, or ``None`` if even the smallest tile doesn't fit."""
    if n > MAX_FUSED_N:
        return None
    for bm in (DEFAULT_BM, 128, 64, 32):
        if cascade_vmem_bytes(n, k, permute=permute, bias=bias,
                              bm=bm) <= VMEM_BUDGET:
            return bm
    return None


def fits_vmem(n: int, k: int, *, permute: bool, bias: bool) -> bool:
    """Whether the order-K fused cascade fits the VMEM budget at size N
    (at any supported row-block size)."""
    return pick_bm(n, k, permute=permute, bias=bias) is not None


def _cascade_kernel(k, relu, x_ref, a_ref, d_ref, bias_ref,
                    c_ref, ct_ref, ct_mid_ref, o_ref):
    """One row-block through all K layers without leaving VMEM."""
    h = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    ct_last = ct_ref[...].astype(jnp.float32)
    ct_mid = (ct_mid_ref[...].astype(jnp.float32)
              if ct_mid_ref is not None else ct_last)
    for i in range(k):  # K is static: unrolled, no dynamic layer indexing
        h1 = h * a_ref[i:i + 1, :].astype(jnp.float32)
        h2 = jnp.dot(h1, c, preferred_element_type=jnp.float32)
        h3 = h2 * d_ref[i:i + 1, :].astype(jnp.float32)
        if bias_ref is not None:
            h3 = h3 + bias_ref[i:i + 1, :].astype(jnp.float32)
        last = i == k - 1
        h = jnp.dot(h3, ct_last if last else ct_mid,
                    preferred_element_type=jnp.float32)
        if relu and not last:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("relu", "bm", "interpret"))
def acdc_cascade_pallas(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array],
    c: jax.Array,
    ct: jax.Array,
    ct_mid: Optional[jax.Array],
    *,
    relu: bool = False,
    bm: int = DEFAULT_BM,
    interpret: bool = False,
) -> jax.Array:
    """Fused order-K cascade over 2-D ``x`` (M, N).

    ``a``/``d``/``bias`` are the stacked (K, N) per-layer diagonals.
    ``ct_mid`` is the column-permuted inverse transform applied between
    layers (pass ``None`` when not riffling); ``ct`` closes the cascade.
    """
    m, n = x.shape
    k = a.shape[0]
    bm = min(bm, max(8, m))
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    grid = (x.shape[0] // bm,)

    stack_spec = pl.BlockSpec((k, n), lambda i: (0, 0))
    mat_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    row_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))

    operands = [x, a, d]
    in_specs = [row_spec, stack_spec, stack_spec]
    if bias is not None:
        operands.append(bias)
        in_specs.append(stack_spec)
    operands += [c, ct]
    in_specs += [mat_spec, mat_spec]
    if ct_mid is not None:
        operands.append(ct_mid)
        in_specs.append(mat_spec)
    variants = {  # (has_bias, has_ct_mid) -> positional-ref wrapper
        (True, True): _cascade_kernel,
        (True, False): _cascade_kernel_nomid,
        (False, True): _cascade_kernel_nobias,
        (False, False): _cascade_kernel_nobias_nomid,
    }
    kernel = functools.partial(
        variants[(bias is not None, ct_mid is not None)], k, relu)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n), x.dtype),
        interpret=interpret,
    )(*operands)
    if pad_m:
        out = out[:m]
    return out


def _cascade_kernel_nobias(k, relu, x_ref, a_ref, d_ref,
                           c_ref, ct_ref, ct_mid_ref, o_ref):
    _cascade_kernel(k, relu, x_ref, a_ref, d_ref, None,
                    c_ref, ct_ref, ct_mid_ref, o_ref)


def _cascade_kernel_nomid(k, relu, x_ref, a_ref, d_ref, bias_ref,
                          c_ref, ct_ref, o_ref):
    _cascade_kernel(k, relu, x_ref, a_ref, d_ref, bias_ref,
                    c_ref, ct_ref, None, o_ref)


def _cascade_kernel_nobias_nomid(k, relu, x_ref, a_ref, d_ref,
                                 c_ref, ct_ref, o_ref):
    _cascade_kernel(k, relu, x_ref, a_ref, d_ref, None,
                    c_ref, ct_ref, None, o_ref)
