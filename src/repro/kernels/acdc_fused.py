"""Fused ACDC Pallas TPU kernel — the "single call" implementation.

TPU adaptation of the paper's section 5.1 fused CUDA kernel.  The GPU
version fuses A-scale -> DCT -> D-scale -> IDCT into one kernel, keeping
intermediates in shared memory so only 8N bytes move through HBM per row.
The TPU version keeps the same fusion structure but replaces the butterfly
DCT with MXU matmuls against the precomputed orthonormal DCT matrix
(DESIGN.md section 3): butterflies are VPU-shaped; the MXU wants 128x128
systolic matmuls.

Memory behaviour per grid step (row-block of ``bm`` rows):

    HBM reads : x tile (bm x N) + C tiles (N x N, reused across the grid and
                therefore cached/streamed once for the whole batch)
    VMEM      : h1, h2, h3 intermediates — never touch HBM
    HBM write : y tile (bm x N)

which is exactly the paper's "minimum 8N bytes moved per layer" once the
transform matrix is amortized over a large batch.  Like the paper's fused
kernel, this path is limited by on-chip memory: both C and C^T tiles must
fit VMEM, so it is used for N <= ``MAX_FUSED_N`` and the two-call
``scaled_matmul`` path covers larger sizes (ops.py picks automatically).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# fp32 C + C^T at N=2048 -> 2 * 16MB exceeds VMEM (~16MB/core on v5e).
# N=1024 -> 2 * 4MB + tiles: fits comfortably.
#
# The whole-cascade fused kernel (acdc_cascade_fused.py) shares this gate
# and adds to the same budget: K stacked (K, N) diagonals (a, d, bias ->
# up to 12 KB * K at N=1024, negligible) and, when riffling, a THIRD N^2
# matrix (the column-permuted C^T for mid-cascade layers) -> ~12 MB of
# matrices at N=1024.  ``acdc_cascade_fused.fits_vmem`` does the exact
# arithmetic and ops.py falls back to the per-layer scan when it fails.
MAX_FUSED_N = 1024
DEFAULT_BM = 256


def _acdc_kernel(x_ref, a_ref, d_ref, bias_ref, c_ref, ct_ref, o_ref):
    """One row-block: y = ((x*a) @ C * d + bias) @ C^T, all in VMEM."""
    x = x_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    h1 = x * a  # (bm, N) * (1, N)
    h2 = jnp.dot(h1, c_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    h3 = h2 * d
    if bias_ref is not None:
        h3 = h3 + bias_ref[...].astype(jnp.float32)
    y = jnp.dot(h3, ct_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def acdc_fused_pallas(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array],
    c: jax.Array,
    ct: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    interpret: bool = False,
) -> jax.Array:
    """Fused ACDC over a 2-D ``x`` of shape (M, N).  N must be <= MAX_FUSED_N
    and a multiple of 128 for the MXU; M is padded to ``bm`` internally.
    """
    m, n = x.shape
    bm = min(bm, max(8, m))
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    grid = (x.shape[0] // bm,)

    a2 = a.reshape(1, n)
    d2 = d.reshape(1, n)
    bias2 = bias.reshape(1, n) if bias is not None else None

    diag_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    mat_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    row_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))

    kernel = _acdc_kernel
    operands = [x, a2, d2]
    in_specs = [row_spec, diag_spec, diag_spec]
    if bias2 is not None:
        operands.append(bias2)
        in_specs.append(diag_spec)
    else:
        kernel = functools.partial(_no_bias_kernel, _acdc_kernel)
    operands += [c, ct]
    in_specs += [mat_spec, mat_spec]

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n), x.dtype),
        interpret=interpret,
    )(*operands)
    if pad_m:
        out = out[:m]
    return out


def _no_bias_kernel(inner, x_ref, a_ref, d_ref, c_ref, ct_ref, o_ref):
    inner(x_ref, a_ref, d_ref, None, c_ref, ct_ref, o_ref)
