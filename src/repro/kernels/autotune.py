"""First-call on-device block-size autotuning for the fused ACDC kernels.

The fused kernels used fixed row blocks (``bm`` = 256 forward / 128
backward, budget-derived for the cascade).  The VMEM-occupancy sweet spot
shifts with N, cascade depth, dtype and TPU generation, so on the first
call for a given ``(N, K, dtype, direction)`` this module times a tiny
on-device sweep over the candidate blocks {64, 128, 256} and memoizes the
winner for the process lifetime.  Off-device (CPU tests / CI, where the
kernels run in interpret mode and timings are meaningless) the sweep is
skipped and the previous fixed constants come back unchanged, so tuned
and untuned runs share one code path.

The call sites (``ops.py``'s custom-VJP impls) are almost always first
hit INSIDE a ``jit`` trace, where omnistaging would stage the sweep's
work as tracers instead of running it.  The sweep therefore escapes the
trace explicitly: sample operands are built concrete under
``jax.ensure_compile_time_eval()`` and each candidate kernel is
dispatched through an AOT ``lower(...).compile()`` executable (compiled
callables run for real whatever the ambient trace state), so the timing
happens on device at trace time and only the chosen ``bm`` (a static
Python int) shapes the traced kernel.

Directions: ``fwd``/``bwd`` (per-layer kernels), ``cascade`` (fused
forward), ``cascade_bwd`` (reverse-sweep backward; candidates filtered
by its stash-inclusive VMEM budget), and ``paged_attn`` (the serving
decode/verify kernel: candidates are (page_chunk, head_block) pairs
packed into the cache's int slot via ``paged_attn.encode_block``,
filtered by the kernel's per-chunk budget, keyed on (head_dim, T)).

Sweep winners also persist across processes: real device sweeps are
spilled to ``results/autotune_cache.json`` (keyed by backend —
fallback constants never leak between backends) and reloaded lazily on
the first TPU-side miss, so repeated ``launch/train`` runs skip the
first-call on-device sweep.  ``REPRO_AUTOTUNE_CACHE=0`` disables the
file; ``REPRO_AUTOTUNE_CACHE_PATH`` relocates it.

Keys carry the transform FAMILY (``core/families.py``): the sweep's
operands are the family's own ``C``/``C^T`` matrices, and a winner swept
for one family is never served to another (different matrix constant ->
different VMEM/MXU behavior is possible even at equal shapes).  Entries
persisted before the family field existed (6-field keys) are migrated on
load by tagging them ``acdc`` — every pre-family sweep ran the DCT — so
e.g. a ``circulant`` run can never reuse a DCT-swept block size.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import families as families_mod
from repro.kernels import acdc_bwd as bwd_mod
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.kernels import acdc_cascade_bwd as cascade_bwd_mod
from repro.kernels import acdc_cascade_fused as cascade_mod
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import paged_attn as paged_attn_mod

#: candidate row blocks, smallest first (the sweep skips ones over budget)
CANDIDATE_BMS = (64, 128, 256)
#: rows in the sweep's sample batch — enough grid steps to see pipelining
SWEEP_ROWS = 1024
#: timing repetitions per candidate (after one compile/warmup call)
SWEEP_REPS = 3

#: set to "0"/"off"/"false" to disable the on-disk sweep-result cache
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

#: representative serving dims for the ``paged_attn`` sweep — the cache
#: key only carries (head_dim, T), so the sweep fixes the rest at the
#: engine defaults; winners are clamped to the real call site's head
#: count by ``paged_attn.clamp_block``
_PAGED_SWEEP = {"hkv": 8, "group": 4, "bs": 16, "mb": 16, "rows": 8,
                "pool": 128}

_CACHE: Dict[Tuple, int] = {}
_PERSIST_LOADED = False

#: real on-device sweeps completed this process, labeled by direction —
#: fallbacks and memo/persist hits do NOT count (a run that shows zero
#: sweeps either hit the disk cache or never touched a TPU)
_SWEEPS = obs_metrics.REGISTRY.counter(
    "autotune_sweeps_total", "on-device block-size sweeps completed",
    labels=("direction",))


def _fallback(direction: str, n: int, k: int, *, bias: bool,
              permute: bool) -> int:
    """The pre-autotune fixed constants (also the no-device answer)."""
    if direction == "fwd":
        return fused_mod.DEFAULT_BM
    if direction == "bwd":
        return bwd_mod.DEFAULT_BM
    if direction == "cascade":
        bm = cascade_mod.pick_bm(n, k, permute=permute, bias=bias)
        return bm if bm is not None else cascade_mod.DEFAULT_BM
    if direction == "cascade_bwd":
        bm = cascade_bwd_mod.pick_bm(n, k, permute=permute, bias=bias)
        return bm if bm is not None else cascade_bwd_mod.DEFAULT_BM
    if direction == "paged_attn":
        # key reuse: n = head_dim, k = T (decode 1 / verify k+1); the
        # sweep's other dims are representative (clamped per call site)
        blk = paged_attn_mod.pick_block(
            hkv=_PAGED_SWEEP["hkv"], dh=n, group=_PAGED_SWEEP["group"],
            t=k, bs=_PAGED_SWEEP["bs"], itemsize=4)
        return paged_attn_mod.encode_block(
            blk if blk is not None else paged_attn_mod.DEFAULT_BLOCK)
    raise ValueError(f"unknown direction {direction!r}")


def _candidates(direction: str, n: int, k: int, *, bias: bool,
                permute: bool):
    if direction == "cascade":
        return [bm for bm in CANDIDATE_BMS
                if cascade_mod.cascade_vmem_bytes(
                    n, k, permute=permute, bias=bias,
                    bm=bm) <= cascade_mod.VMEM_BUDGET]
    if direction == "cascade_bwd":
        return [bm for bm in CANDIDATE_BMS
                if cascade_bwd_mod.cascade_bwd_vmem_bytes(
                    n, k, permute=permute, bias=bias,
                    bm=bm) <= cascade_mod.VMEM_BUDGET]
    if direction == "paged_attn":
        # page-chunk x head-block grid, encoded into the cache's int
        # slot; budget is the kernel's per-chunk VMEM model
        return [paged_attn_mod.encode_block((pc, bh))
                for pc in paged_attn_mod.PAGE_CHUNKS
                for bh in paged_attn_mod.HEAD_BLOCKS
                if _PAGED_SWEEP["hkv"] % bh == 0
                and paged_attn_mod.paged_attn_vmem_bytes(
                    bs=_PAGED_SWEEP["bs"], dh=n,
                    group=_PAGED_SWEEP["group"], t=k, pc=pc, bh=bh,
                    itemsize=4) <= cascade_mod.VMEM_BUDGET]
    return list(CANDIDATE_BMS)


# ---------------------------------------------------------------------------
# Persistent sweep cache (results/autotune_cache.json).
#
# Sweeps are memoized per process; a fresh ``launch/train`` run used to
# re-pay the first-call on-device sweep for every (N, K, dtype,
# direction).  Swept winners are spilled to a small JSON and reloaded on
# startup.  Only REAL device sweeps are persisted (the file records the
# backend and is ignored under any other), so CPU fallback constants
# never leak into a TPU run.  Set REPRO_AUTOTUNE_CACHE=0 to disable.
# ---------------------------------------------------------------------------

def _backend() -> str:
    return jax.default_backend()


def _persist_enabled() -> bool:
    return os.environ.get(CACHE_ENV, "1").lower() not in (
        "0", "off", "false", "no")


def _cache_path() -> str:
    override = os.environ.get(CACHE_ENV + "_PATH")
    if override:
        return override
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "results", "autotune_cache.json")


def _key_str(key: Tuple) -> str:
    return "|".join(str(p) for p in key)


def _key_from_str(s: str) -> Tuple:
    parts = s.split("|")
    if len(parts) == 6:
        # pre-family entry: every sweep recorded before the transform
        # registry existed ran the DCT, so migrate rather than discard —
        # but NEVER let another family inherit it.
        parts.append("acdc")
    direction, n, k, dtype, bias, permute, family = parts
    return (direction, int(n), int(k), dtype,
            bias == "True", permute == "True", family)


def _load_persistent() -> None:
    """Merge on-disk sweep winners into the in-process memo (lazy, once)."""
    global _PERSIST_LOADED
    if _PERSIST_LOADED:
        return
    _PERSIST_LOADED = True
    if not _persist_enabled():
        return
    try:
        with open(_cache_path()) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return
    if blob.get("backend") != _backend():
        return
    for key_s, bm in blob.get("entries", {}).items():
        try:
            _CACHE.setdefault(_key_from_str(key_s), int(bm))
        except (ValueError, TypeError):
            continue


def _save_persistent(key: Tuple, bm: int) -> None:
    """Record one swept winner on disk (read-merge-write, best effort)."""
    if not _persist_enabled():
        return
    path = _cache_path()
    entries: Dict[str, int] = {}
    try:
        with open(path) as f:
            blob = json.load(f)
        if blob.get("backend") == _backend():
            entries = dict(blob.get("entries", {}))
    except (OSError, ValueError):
        pass
    entries[_key_str(key)] = int(bm)
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"backend": _backend(), "entries": entries}, f,
                      indent=2, sort_keys=True)
    except OSError:
        pass


def _make_runner(direction: str, n: int, k: int, dtype, *, bias: bool,
                 permute: bool, family: str = "acdc",
                 interpret: bool) -> Callable[[int], Callable[[], None]]:
    """Build ``build(bm) -> run()``: an AOT-compiled single kernel call on
    sample operands.  Compilation happens in ``build`` (outside the timed
    region); ``run`` only dispatches and blocks.  Operands are created
    under ``ensure_compile_time_eval`` and the call goes through
    ``lower(...).compile()`` so both stay concrete when the sweep is
    first hit inside an enclosing ``jit`` trace."""
    if direction == "paged_attn":
        return _make_paged_runner(n, k, dtype, interpret=interpret)
    fam = families_mod.get_family(family)
    with jax.ensure_compile_time_eval():
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (SWEEP_ROWS, n), dtype)
        c, ct = fam.matrices(n, jnp.float32)
        if direction in ("cascade", "cascade_bwd"):
            a = jnp.ones((k, n), jnp.float32)
            d = jnp.ones((k, n), jnp.float32)
            b = jnp.zeros((k, n), jnp.float32) if bias else None
            ct_mid = (ct[:, fam.riffle(n)] if permute else None)
        else:
            a = jnp.ones((n,), jnp.float32)
            d = jnp.ones((n,), jnp.float32)
            b = jnp.zeros((n,), jnp.float32) if bias else None
        if direction in ("bwd", "cascade_bwd"):
            g = jax.random.normal(jax.random.fold_in(key, 1),
                                  (SWEEP_ROWS, n), dtype)

    def build(bm: int) -> Callable[[], None]:
        if direction == "cascade":
            args = (x, a, d, b, c, ct, ct_mid)
            compiled = cascade_mod.acdc_cascade_pallas.lower(
                *args, relu=False, bm=bm, interpret=interpret).compile()
        elif direction == "cascade_bwd":
            args = (x, g, a, d, b, c, ct, ct_mid)
            compiled = cascade_bwd_mod.acdc_cascade_bwd_pallas.lower(
                *args, relu=False, bm=bm, interpret=interpret).compile()
        elif direction == "fwd":
            args = (x, a, d, b, c, ct)
            compiled = fused_mod.acdc_fused_pallas.lower(
                *args, bm=bm, interpret=interpret).compile()
        else:
            args = (x, g, a, d, c, ct)
            compiled = bwd_mod.acdc_bwd_pallas.lower(
                *args, with_bias=bias, bm=bm, interpret=interpret).compile()

        def run() -> None:
            jax.block_until_ready(compiled(*args))

        run.bm = bm
        return run

    return build


def _make_paged_runner(dh: int, t: int, dtype, *,
                       interpret: bool) -> Callable[[int], Callable[[], None]]:
    """``build(encoded_block) -> run()`` for the paged-attention sweep:
    one fused decode/verify dispatch on representative serving operands
    (``_PAGED_SWEEP`` dims, rows mid-stream so pages actually stream)."""
    dims = _PAGED_SWEEP
    hkv, group, bs = dims["hkv"], dims["group"], dims["bs"]
    rows, mb, pool = dims["rows"], dims["mb"], dims["pool"]
    hq = hkv * group
    with jax.ensure_compile_time_eval():
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (rows, t, hq, dh), dtype)
        kn = jax.random.normal(jax.random.fold_in(key, 1),
                               (rows, t, hkv, dh), dtype)
        vn = jax.random.normal(jax.random.fold_in(key, 2),
                               (rows, t, hkv, dh), dtype)
        kp = jnp.zeros((pool + 1, bs, hkv, dh), dtype)
        vp = jnp.zeros((pool + 1, bs, hkv, dh), dtype)
        tbl = jnp.arange(rows * mb, dtype=jnp.int32).reshape(rows, mb) % pool
        pos = jnp.full((rows,), (mb * bs) // 2, jnp.int32)
        win = jnp.int32(0)
        args = (q, kn, vn, kp, vp, tbl, pos, win)

    def build(enc: int) -> Callable[[], None]:
        pc, bh = paged_attn_mod.decode_block(enc)
        fn = jax.jit(functools.partial(
            paged_attn_mod.paged_attention, softcap=0.0, page_chunk=pc,
            head_block=bh, interpret=interpret))
        compiled = fn.lower(*args).compile()

        def run() -> None:
            jax.block_until_ready(compiled(*args))

        run.bm = enc
        return run

    return build


def sweep(direction: str, n: int, k: int = 1, dtype=jnp.float32, *,
          bias: bool = False, permute: bool = False,
          family: str = "acdc", interpret: bool = False,
          timer: Optional[Callable[[Callable[[], None]], float]] = None) -> int:
    """Time every in-budget candidate and return the fastest ``bm``.

    ``timer`` (seconds for one call of a nullary thunk) is injectable for
    tests; the default runs one warmup/compile call then best-of-
    ``SWEEP_REPS`` wall clock.
    """
    cands = _candidates(direction, n, k, bias=bias, permute=permute)
    if not cands:
        return _fallback(direction, n, k, bias=bias, permute=permute)
    build = _make_runner(direction, n, k, dtype, bias=bias, permute=permute,
                         family=family, interpret=interpret)

    def default_timer(thunk: Callable[[], None]) -> float:
        thunk()  # warmup outside the timed reps (compile already done)
        best = float("inf")
        for _ in range(SWEEP_REPS):
            t0 = time.perf_counter()
            thunk()
            best = min(best, time.perf_counter() - t0)
        return best

    timer = timer or default_timer
    timings = [(timer(build(bm)), bm) for bm in cands]
    return min(timings)[1]


def autotuned_bm(direction: str, n: int, k: int = 1, dtype=jnp.float32, *,
                 bias: bool = False, permute: bool = False,
                 family: str = "acdc") -> int:
    """Memoized block size for ``(N, K, dtype, direction, family)`` (+ the
    budget knobs bias/permute): on-device sweep on TPU, fixed fallback
    elsewhere.  ``family`` keys the memo AND shapes the sweep operands —
    a winner timed on one family's matrices never answers for another's.
    """
    key = (direction, int(n), int(k), jnp.dtype(dtype).name, bool(bias),
           bool(permute), family)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    if _backend() != "tpu":
        bm = _fallback(direction, n, k, bias=bias, permute=permute)
    else:
        _load_persistent()
        hit = _CACHE.get(key)
        if hit is not None:
            return hit
        try:
            bm = sweep(direction, n, k, dtype, bias=bias, permute=permute,
                       family=family)
            _save_persistent(key, bm)
            _SWEEPS.labels(direction=direction).inc()
            obs_trace.instant_global("autotune", "sweep",
                                     direction=direction,
                                     key=_key_str(key), winner=int(bm))
        except Exception:
            bm = _fallback(direction, n, k, bias=bias, permute=permute)
    _CACHE[key] = bm
    return bm
