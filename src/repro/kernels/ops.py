"""Public jit'd wrappers around the Pallas kernels.

``acdc_fused`` is the production entry point used by the model zoo when
``method='pallas'``:

* N <= MAX_FUSED_N      -> single fused kernel (paper's "single call");
* larger N              -> two chained ``scaled_matmul`` kernels with the
                           diagonals fused (paper's "multiple call");
* custom VJP that RECOMPUTES the transform-domain intermediate ``h2`` in
  the backward pass instead of storing it — the paper's section 5.3
  memory/runtime trade, expressed as a custom_vjp.

The backward formulas are the paper's eqs. (10)-(14):

    dL/dbias = sum_rows (g C)
    dL/dd    = sum_rows h2 * (g C),      h2 = (x*a) C   (recomputed)
    dL/da    = sum_rows x * ((g C * d) C^T)
    dL/dx    = a * ((g C * d) C^T)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import transforms
from repro.core.acdc import MATMUL_MAX_N
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import scaled_matmul as smm_mod

_INTERPRET = jax.default_backend() != "tpu"


def _flatten(x):
    return x.reshape(-1, x.shape[-1]), x.shape


def _acdc_fwd_impl(x2, a, d, bias, *, interpret):
    n = x2.shape[-1]
    c = transforms.dct_matrix(n, dtype=jnp.float32)
    ct = transforms.idct_matrix(n, dtype=jnp.float32)
    if n <= fused_mod.MAX_FUSED_N:
        return fused_mod.acdc_fused_pallas(x2, a, d, bias, c, ct,
                                           interpret=interpret)
    # Two-call path: h2 lands in HBM exactly once.  A and D are fused as
    # pre-scales; the bias-on-D commutes through the final matmul as
    # bias @ C^T (an O(N^2) one-off, amortized over the batch).
    h2 = smm_mod.scaled_matmul_pallas(x2, c, pre=a, interpret=interpret)
    bias_t = None
    if bias is not None:
        bias_t = (bias.astype(jnp.float32) @ ct).astype(x2.dtype)
    return smm_mod.scaled_matmul_pallas(h2, ct, pre=d, bias=bias_t,
                                        interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def acdc_fused(x, a, d, bias):
    """Fused ACDC: ``y = ((x*a) C * d + bias) C^T`` along the last axis."""
    x2, shape = _flatten(x)
    y = _acdc_fwd_impl(x2, a, d, bias, interpret=_INTERPRET)
    return y.reshape(shape)


def _acdc_bwd_core(x, a, d, g):
    """Shared backward math (paper eqs. 10-14); returns (dx, da, dd, gc).

    ``gc = g C`` is reused for the bias gradient when a bias exists.
    """
    n = x.shape[-1]
    x2, shape = _flatten(x)
    g2, _ = _flatten(g)
    dct = transforms.dct_via_matmul if n <= MATMUL_MAX_N else transforms.dct
    idct = (transforms.idct_via_matmul if n <= MATMUL_MAX_N
            else transforms.idct)
    gc = dct(g2.astype(jnp.float32))
    h2 = dct(x2.astype(jnp.float32) * a.astype(jnp.float32))  # recompute (paper 5.3)
    dd = jnp.sum(h2 * gc, axis=0).astype(d.dtype)
    dh1 = idct(gc * d.astype(jnp.float32))
    da = jnp.sum(x2.astype(jnp.float32) * dh1, axis=0).astype(a.dtype)
    dx = (a.astype(jnp.float32) * dh1).astype(x.dtype).reshape(shape)
    return dx, da, dd, gc


def _acdc_vjp_fwd(x, a, d, bias):
    y = acdc_fused(x, a, d, bias)
    return y, (x, a, d)


def _acdc_vjp_bwd(res, g):
    x, a, d = res
    dx, da, dd, gc = _acdc_bwd_core(x, a, d, g)
    dbias = jnp.sum(gc, axis=0).astype(d.dtype)
    return dx, da, dd, dbias


acdc_fused.defvjp(_acdc_vjp_fwd, _acdc_vjp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def acdc_fused_nobias(x, a, d):
    """Bias-free fused ACDC: ``y = ((x*a) C * d) C^T``.

    A separate primitive (not ``acdc_fused`` with zeros): the LM path sets
    ``bias=False`` on every projection, and a dummy zero bias would pay the
    broadcast add in the forward AND a full (M, N) reduction for its VJP on
    every call.
    """
    x2, shape = _flatten(x)
    y = _acdc_fwd_impl(x2, a, d, None, interpret=_INTERPRET)
    return y.reshape(shape)


def _acdc_nobias_vjp_fwd(x, a, d):
    return acdc_fused_nobias(x, a, d), (x, a, d)


def _acdc_nobias_vjp_bwd(res, g):
    x, a, d = res
    dx, da, dd, _ = _acdc_bwd_core(x, a, d, g)
    return dx, da, dd


acdc_fused_nobias.defvjp(_acdc_nobias_vjp_fwd, _acdc_nobias_vjp_bwd)


def acdc_fused_op(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """User-facing fused ACDC; dispatches on the optional bias."""
    if bias is None:
        return acdc_fused_nobias(x, a, d)
    return acdc_fused(x, a, d, bias)


def scaled_matmul(
    x: jax.Array,
    w: jax.Array,
    pre: Optional[jax.Array] = None,
    post: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Blocked scaled matmul on the last axis of ``x``."""
    x2, shape = _flatten(x)
    y = smm_mod.scaled_matmul_pallas(x2, w, pre, post, bias,
                                     interpret=_INTERPRET)
    return y.reshape(*shape[:-1], w.shape[-1])
