"""Public jit'd wrappers around the Pallas kernels.

``acdc_fused`` is the production entry point used by the model zoo when
``method='pallas'``:

* N <= MAX_FUSED_N      -> single fused kernel (paper's "single call");
* larger N              -> two chained ``scaled_matmul`` kernels with the
                           diagonals fused (paper's "multiple call");
* custom VJP that RECOMPUTES the transform-domain intermediate ``h2`` in
  the backward pass instead of storing it — the paper's section 5.3
  memory/runtime trade, expressed as a custom_vjp.  The backward itself
  is the fused Pallas kernel in ``acdc_bwd.py`` (one pass per row-block,
  diagonal grads accumulated in VMEM scratch); above ``MAX_FUSED_N`` it
  degrades to chained ``scaled_matmul`` kernels, never to bare XLA
  matmuls.

``acdc_cascade_op`` is the order-K entry point: the whole cascade —
including the interleaved ReLU and riffle permutation of the CaffeNet
configuration — runs as ONE Pallas kernel (``acdc_cascade_fused.py``)
moving 8N bytes per row instead of 8KN, behind a cascade-level custom
VJP.  The primary backward is the reverse-sweep kernel
(``acdc_cascade_bwd.py``): one Pallas call walking all K layers in
reverse with the cotangent resident in VMEM and layer inputs recomputed
on-chip — 12N bytes/row independent of K.  When its VMEM budget (which
includes a (K-1)-deep activation stash) doesn't fit, the backward falls
back to the per-layer HBM-remat scan; when the whole cascade exceeds
the forward fused budget both directions fall back to the per-layer
scan (each layer still fused forward + backward).  Routing decisions
are counted in ``CASCADE_BWD_DISPATCHES`` for the bench/CI regression
gate.

The backward formulas are the paper's eqs. (10)-(14):

    dL/dbias = sum_rows (g C)
    dL/dd    = sum_rows h2 * (g C),      h2 = (x*a) C   (recomputed)
    dL/da    = sum_rows x * ((g C * d) C^T)
    dL/dx    = a * ((g C * d) C^T)

Every op takes a ``family`` argument (static, default ``'acdc'``)
selecting the transform from :mod:`repro.core.families`: the kernels
only require ``C`` real orthonormal with ``C^-1 = C^T`` — true for the
DCT-II, the real-DFT basis (``'circulant'``) and the normalized
Walsh-Hadamard (``'hadamard'``) — so one kernel body serves the whole
zoo; the family supplies the ``C``/``C^T`` operands, the mid-cascade
permuted-columns fold, and the autotune cache key.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import families as families_mod
from repro.core import transforms
from repro.kernels import acdc_bwd as bwd_mod
from repro.kernels import acdc_cascade_bwd as cascade_bwd_mod
from repro.kernels import acdc_cascade_fused as cascade_mod
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import autotune
from repro.kernels import paged_attn as paged_attn_mod
from repro.kernels import scaled_matmul as smm_mod
from repro.obs.metrics import REGISTRY, CounterDict

_INTERPRET = jax.default_backend() != "tpu"

#: trace-time routing decisions of the cascade backward, for benches/CI:
#: every time a cascade VJP backward is traced, exactly one bucket
#: increments.  ``reverse_sweep`` is the fused O(1)-in-K kernel;
#: ``per_layer_scan`` the HBM-remat fallback.  (Counts tracings, not
#: dispatches — a jit cache hit re-runs the kernel without retracing.)
#: The historical dict names remain the canonical mutation surface, but
#: since PR 10 they are shims over labeled counters in the process-
#: global obs registry — ``kernel_cascade_bwd_dispatches_total{route=}``
#: — so serving exporters report them alongside engine metrics.
CASCADE_BWD_DISPATCHES = CounterDict(
    REGISTRY.counter("kernel_cascade_bwd_dispatches_total",
                     "trace-time cascade-backward routing decisions",
                     labels=("route",)),
    ("reverse_sweep", "per_layer_scan"))

#: trace-time routing of the paged-attention decode/verify step, same
#: contract as ``CASCADE_BWD_DISPATCHES``: ``fused`` is the block-table
#: streaming kernel (``paged_attn.py``), ``gather`` the materialized
#: ``k_pages[tbl]`` fallback kept for over-budget shapes and CPU
#: interpret runs.  Registry metric:
#: ``kernel_paged_attn_dispatches_total{route=}``.
PAGED_ATTN_DISPATCHES = CounterDict(
    REGISTRY.counter("kernel_paged_attn_dispatches_total",
                     "trace-time paged-attention routing decisions",
                     labels=("route",)),
    ("fused", "gather"))


def paged_attn_route(hkv: int, dh: int, group: int, t: int, bs: int,
                     dtype) -> Optional[tuple]:
    """Trace-time dispatch for the paged-attention kernel.

    Returns the ``(page_chunk, head_block)`` pair to run the fused
    kernel with, or None to keep the gather fallback.  Policy mirrors
    the cascade kernels: fused on real devices when a block fits the
    per-chunk VMEM budget (block sizes from the autotune ``paged_attn``
    direction, clamped to the call site's head count), gather on CPU
    interpret runs — unless ``paged_attn.FORCE_FUSED`` is set, which
    parity tests and benches use to drive the kernel in interpret mode.
    Every trace increments exactly one ``PAGED_ATTN_DISPATCHES`` bucket.
    """
    itemsize = jnp.dtype(dtype).itemsize
    if not (paged_attn_mod.FORCE_FUSED or jax.default_backend() == "tpu"):
        PAGED_ATTN_DISPATCHES["gather"] += 1
        return None
    enc = autotune.autotuned_bm("paged_attn", dh, t, dtype)
    blk = paged_attn_mod.clamp_block(
        paged_attn_mod.decode_block(enc), hkv=hkv, dh=dh, group=group,
        t=t, bs=bs, itemsize=itemsize)
    if blk is None:
        PAGED_ATTN_DISPATCHES["gather"] += 1
        return None
    PAGED_ATTN_DISPATCHES["fused"] += 1
    return blk


def _flatten(x):
    return x.reshape(-1, x.shape[-1]), x.shape


def _family_mats(family, n):
    """The family's fp32 ``(C, C^T)`` kernel operand pair at size ``n``."""
    return families_mod.get_family(family).matrices(n, jnp.float32)


def _acdc_fwd_impl(x2, a, d, bias, *, family="acdc", interpret):
    n = x2.shape[-1]
    c, ct = _family_mats(family, n)
    if n <= fused_mod.MAX_FUSED_N:
        bm = autotune.autotuned_bm("fwd", n, dtype=x2.dtype,
                                   bias=bias is not None, family=family)
        return fused_mod.acdc_fused_pallas(x2, a, d, bias, c, ct, bm=bm,
                                           interpret=interpret)
    # Two-call path: h2 lands in HBM exactly once.  A and D are fused as
    # pre-scales; the bias-on-D commutes through the final matmul as
    # bias @ C^T (an O(N^2) one-off, amortized over the batch).
    h2 = smm_mod.scaled_matmul_pallas(x2, c, pre=a, interpret=interpret)
    bias_t = None
    if bias is not None:
        bias_t = (bias.astype(jnp.float32) @ ct).astype(x2.dtype)
    return smm_mod.scaled_matmul_pallas(h2, ct, pre=d, bias=bias_t,
                                        interpret=interpret)


def _acdc_bwd_impl(x2, a, d, g2, *, family="acdc", with_bias=True,
                   interpret):
    """Pallas backward dispatch; returns (dx2, da, dd, dbias), diagonal
    grads in fp32 (the VMEM accumulator precision).  ``with_bias=False``
    skips the dbias reduction entirely (dbias comes back ``None``)."""
    n = x2.shape[-1]
    c, ct = _family_mats(family, n)
    if n <= fused_mod.MAX_FUSED_N:
        bm = autotune.autotuned_bm("bwd", n, dtype=x2.dtype,
                                   bias=with_bias, family=family)
        return bwd_mod.acdc_bwd_pallas(x2, g2, a, d, c, ct,
                                       with_bias=with_bias, bm=bm,
                                       interpret=interpret)
    return bwd_mod.acdc_bwd_two_call(x2, g2, a, d, c, ct,
                                     with_bias=with_bias,
                                     interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_bias(family, x, a, d, bias):
    x2, shape = _flatten(x)
    y = _acdc_fwd_impl(x2, a, d, bias, family=family, interpret=_INTERPRET)
    return y.reshape(shape)


def _fused_bias_fwd(family, x, a, d, bias):
    return _fused_bias(family, x, a, d, bias), (x, a, d, bias)


def _fused_bias_bwd(family, res, g):
    x, a, d, bias = res
    x2, shape = _flatten(x)
    g2, _ = _flatten(g)
    dx2, da, dd, db = _acdc_bwd_impl(x2, a, d, g2, family=family,
                                     interpret=_INTERPRET)
    return (dx2.reshape(shape), da.astype(a.dtype), dd.astype(d.dtype),
            db.astype(bias.dtype))


_fused_bias.defvjp(_fused_bias_fwd, _fused_bias_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_nobias(family, x, a, d):
    x2, shape = _flatten(x)
    y = _acdc_fwd_impl(x2, a, d, None, family=family, interpret=_INTERPRET)
    return y.reshape(shape)


def _fused_nobias_fwd(family, x, a, d):
    return _fused_nobias(family, x, a, d), (x, a, d)


def _fused_nobias_bwd(family, res, g):
    x, a, d = res
    x2, shape = _flatten(x)
    g2, _ = _flatten(g)
    dx2, da, dd, _ = _acdc_bwd_impl(x2, a, d, g2, family=family,
                                    with_bias=False, interpret=_INTERPRET)
    return dx2.reshape(shape), da.astype(a.dtype), dd.astype(d.dtype)


_fused_nobias.defvjp(_fused_nobias_fwd, _fused_nobias_bwd)


def acdc_fused(x, a, d, bias, family="acdc"):
    """Fused layer ``y = ((x*a) C * d + bias) C^T`` along the last axis;
    ``C`` from the transform family registry."""
    return _fused_bias(family, x, a, d, bias)


def acdc_fused_nobias(x, a, d, family="acdc"):
    """Bias-free fused layer: ``y = ((x*a) C * d) C^T``.

    A separate primitive (not ``acdc_fused`` with zeros): the LM path sets
    ``bias=False`` on every projection, and a dummy zero bias would pay the
    broadcast add in the forward AND a full (M, N) reduction for its VJP on
    every call.
    """
    return _fused_nobias(family, x, a, d)


def acdc_fused_op(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    family: str = "acdc",
) -> jax.Array:
    """User-facing fused layer; dispatches on the optional bias."""
    if bias is None:
        return _fused_nobias(family, x, a, d)
    return _fused_bias(family, x, a, d, bias)


# ---------------------------------------------------------------------------
# Order-K cascade: whole-cascade fusion + cascade-level custom VJP.
# ---------------------------------------------------------------------------

def _cascade_fwd_impl(x2, a, d, bias, relu, permute, family, *, interpret):
    n = x2.shape[-1]
    fam = families_mod.get_family(family)
    c, ct = fam.matrices(n, jnp.float32)
    ct_mid = None
    if permute:
        # Fold the riffle into the mid-cascade inverse transform:
        # (z @ C^T)[:, p] == z @ C^T[:, p] — no in-kernel gather.
        ct_mid = ct[:, fam.riffle(n)]
    # Row block autotuned within the VMEM budget left by the transform
    # matrices (fixed pick_bm answer off-device); the dispatcher
    # guaranteed some block fits before routing here.
    bm = autotune.autotuned_bm("cascade", n, a.shape[0], x2.dtype,
                               bias=bias is not None, permute=permute,
                               family=family)
    # named_scope costs only at trace time: it labels the jaxpr/HLO so
    # profiler captures show the cascade as one named row
    with jax.named_scope("acdc_cascade_fwd"):
        return cascade_mod.acdc_cascade_pallas(x2, a, d, bias, c, ct,
                                               ct_mid, relu=relu, bm=bm,
                                               interpret=interpret)


def _cascade_bwd_fused(relu, permute, x, a, d, bias, g, family="acdc"):
    """Reverse-sweep cascade backward: ONE Pallas kernel walks all K
    layers in reverse with the cotangent resident in VMEM, recomputing
    layer inputs on-chip (``acdc_cascade_bwd.py``) — 12N HBM bytes/row
    independent of K, symmetric with the fused forward."""
    n = x.shape[-1]
    k = a.shape[0]
    x2, shape = _flatten(x)
    g2, _ = _flatten(g)
    fam = families_mod.get_family(family)
    c, ct = fam.matrices(n, jnp.float32)
    ct_mid = ct[:, fam.riffle(n)] if permute else None
    bm = autotune.autotuned_bm("cascade_bwd", n, k, x2.dtype,
                               bias=bias is not None, permute=permute,
                               family=family)
    with jax.named_scope("acdc_cascade_bwd_reverse_sweep"):
        dx, da, dd, db = cascade_bwd_mod.acdc_cascade_bwd_pallas(
            x2, g2, a, d, bias, c, ct, ct_mid, relu=relu, bm=bm,
            interpret=_INTERPRET)
    dx = dx.reshape(shape)
    if bias is None:
        return dx, da.astype(a.dtype), dd.astype(d.dtype)
    return (dx, da.astype(a.dtype), dd.astype(d.dtype),
            db.astype(bias.dtype))


def _cascade_bwd_dispatch(relu, permute, family, x, a, d, bias, g):
    """Primary VJP routing: reverse-sweep kernel when its (deeper) VMEM
    budget fits, else the per-layer HBM-remat scan.  The budgets differ —
    the backward stashes (K-1) row blocks — so a cascade can run fused
    forward and still fall back here."""
    n = x.shape[-1]
    k = a.shape[0]
    if cascade_bwd_mod.fits_vmem(n, k, permute=permute,
                                 bias=bias is not None):
        CASCADE_BWD_DISPATCHES["reverse_sweep"] += 1
        return _cascade_bwd_fused(relu, permute, x, a, d, bias, g,
                                  family=family)
    CASCADE_BWD_DISPATCHES["per_layer_scan"] += 1
    return _cascade_bwd_core(relu, permute, x, a, d, bias, g,
                             family=family)


def _cascade_bwd_core(relu, permute, x, a, d, bias, g, family="acdc"):
    """Cascade backward fallback: recompute per-layer inputs to HBM
    (section 5.3 trade at cascade scope — the fused forward stores
    NOTHING but x), then run the fused per-layer backward kernel in
    reverse under ``lax.scan``.  O(KN) bytes/row; used only when the
    reverse-sweep kernel's VMEM budget doesn't fit."""
    n = x.shape[-1]
    x2, shape = _flatten(x)
    g2, _ = _flatten(g)
    interp = _INTERPRET
    perm = inv_perm = None
    if permute:
        p = families_mod.get_family(family).riffle(n)
        perm = jnp.asarray(p)
        inv_perm = jnp.asarray(transforms.invert_permutation(p))

    with_bias = bias is not None
    layers = {"a": a, "d": d}
    if with_bias:
        layers["bias"] = bias

    def fstep(h, layer):
        z = _acdc_fwd_impl(h, layer["a"], layer["d"], layer.get("bias"),
                           family=family, interpret=interp)
        hn = jnp.maximum(z, 0) if relu else z
        if perm is not None:
            hn = hn[:, perm]
        # the z residual exists only to rebuild the ReLU mask — don't
        # stack a (K-1, M, N) tensor in HBM for linear cascades.
        return hn, (h, z) if relu else h

    # Recompute only the K-1 interleaved layers: hs[i] is the input to
    # layer i, zs[i] its pre-interleave output, and the final carry is
    # the last layer's input (its own forward output is never needed).
    head = jax.tree.map(lambda p: p[:-1], layers)
    if relu:
        h_last, (hs, zs) = jax.lax.scan(fstep, x2, head)
    else:
        h_last, hs = jax.lax.scan(fstep, x2, head)

    # Last layer: the upstream cotangent applies directly (no interleave
    # after the final layer).
    dh, da_k, dd_k, db_k = _acdc_bwd_impl(h_last, a[-1], d[-1], g2,
                                          family=family,
                                          with_bias=with_bias,
                                          interpret=interp)

    def bstep(gcur, inp):
        if relu:
            h_i, z_i, layer = inp
        else:
            h_i, layer = inp
        gz = gcur[:, inv_perm] if inv_perm is not None else gcur
        if relu:
            gz = jnp.where(z_i > 0, gz, jnp.zeros_like(gz))
        dx, da_i, dd_i, db_i = _acdc_bwd_impl(h_i, layer["a"], layer["d"],
                                              gz, family=family,
                                              with_bias=with_bias,
                                              interpret=interp)
        return dx, (da_i, dd_i, db_i)

    xs = (hs, zs, head) if relu else (hs, head)
    dh, (das, dds, dbs) = jax.lax.scan(bstep, dh, xs, reverse=True)

    da = jnp.concatenate([das, da_k[None]], axis=0).astype(a.dtype)
    dd = jnp.concatenate([dds, dd_k[None]], axis=0).astype(d.dtype)
    dx = dh.reshape(shape)
    if bias is None:
        return dx, da, dd
    db = jnp.concatenate([dbs, db_k[None]], axis=0).astype(bias.dtype)
    return dx, da, dd, db


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _cascade_bias(relu, permute, family, x, a, d, bias):
    x2, shape = _flatten(x)
    y = _cascade_fwd_impl(x2, a, d, bias, relu, permute, family,
                          interpret=_INTERPRET)
    return y.reshape(shape)


def _cascade_bias_fwd(relu, permute, family, x, a, d, bias):
    return (_cascade_bias(relu, permute, family, x, a, d, bias),
            (x, a, d, bias))


def _cascade_bias_bwd(relu, permute, family, res, g):
    x, a, d, bias = res
    return _cascade_bwd_dispatch(relu, permute, family, x, a, d, bias, g)


_cascade_bias.defvjp(_cascade_bias_fwd, _cascade_bias_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _cascade_nobias(relu, permute, family, x, a, d):
    x2, shape = _flatten(x)
    y = _cascade_fwd_impl(x2, a, d, None, relu, permute, family,
                          interpret=_INTERPRET)
    return y.reshape(shape)


def _cascade_nobias_fwd(relu, permute, family, x, a, d):
    return _cascade_nobias(relu, permute, family, x, a, d), (x, a, d)


def _cascade_nobias_bwd(relu, permute, family, res, g):
    x, a, d = res
    return _cascade_bwd_dispatch(relu, permute, family, x, a, d, None, g)


_cascade_nobias.defvjp(_cascade_nobias_fwd, _cascade_nobias_bwd)


def _cascade_per_layer(x, a, d, bias, relu, permute, family="acdc"):
    """Fallback when the whole cascade exceeds the fused VMEM budget:
    ``lax.scan`` over per-layer fused ops (8KN bytes/row, each layer still
    a fused forward + fused backward)."""
    n = x.shape[-1]
    fam = families_mod.get_family(family)
    perm = jnp.asarray(fam.riffle(n)) if permute else None
    layers = {"a": a, "d": d}
    if bias is not None:
        layers["bias"] = bias

    def body(h, layer):
        y = acdc_fused_op(h, layer["a"], layer["d"], layer.get("bias"),
                          family=family)
        if relu:
            y = jax.nn.relu(y)
        if perm is not None:
            y = y[..., perm]
        return y, None

    head = jax.tree.map(lambda p: p[:-1], layers)
    last = jax.tree.map(lambda p: p[-1], layers)
    h, _ = jax.lax.scan(body, x, head)
    return acdc_fused_op(h, last["a"], last["d"], last.get("bias"),
                         family=family)


def acdc_cascade_op(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    relu: bool = False,
    permute: bool = False,
    family: str = "acdc",
) -> jax.Array:
    """Order-K fused cascade: stacked (K, N) diagonals, one kernel.

    Dispatch: K == 1 degenerates to the single-layer op; cascades that fit
    the fused kernel's VMEM budget run whole-cascade fused (8N bytes/row,
    independent of K) behind the cascade-level custom VJP; anything larger
    falls back to the per-layer scan.  ``family`` picks the transform
    (static — one compiled program per family).
    """
    k = a.shape[0]
    if k == 1:
        return acdc_fused_op(x, a[0], d[0],
                             None if bias is None else bias[0],
                             family=family)
    n = x.shape[-1]
    if not cascade_mod.fits_vmem(n, k, permute=permute,
                                 bias=bias is not None):
        return _cascade_per_layer(x, a, d, bias, relu, permute, family)
    if bias is None:
        return _cascade_nobias(relu, permute, family, x, a, d)
    return _cascade_bias(relu, permute, family, x, a, d, bias)


def scaled_matmul(
    x: jax.Array,
    w: jax.Array,
    pre: Optional[jax.Array] = None,
    post: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Blocked scaled matmul on the last axis of ``x``."""
    x2, shape = _flatten(x)
    y = smm_mod.scaled_matmul_pallas(x2, w, pre, post, bias,
                                     interpret=_INTERPRET)
    return y.reshape(*shape[:-1], w.shape[-1])
