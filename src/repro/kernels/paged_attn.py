"""Fused paged-attention decode/verify kernel — the block-table walk.

The gather path in ``models/attention.py`` materializes every slot's
ENTIRE virtual K/V view ``(B, MB*bs, Hkv, Dh)`` via ``k_pages[tbl]``
before SDPA, so a slot 10 tokens into a 4096-token table reads ~400x
the bytes it needs.  This kernel (vLLM-style) never builds that view:

* each grid program ``(slot, head-block)`` walks its slot's block table
  (scalar-prefetched into SMEM) and DMAs only the *mapped, in-frontier*
  pages of K/V from the pool (``pltpu.ANY`` memory space) into a VMEM
  chunk buffer, ``page_chunk`` pages per round;
* attention runs as an online softmax (flash-style running max m and
  denominator l in fp32) per chunk, with the causal/window mask computed
  from ``position`` — chunks wholly outside a sliding window are skipped
  via a per-row start chunk, and streaming stops at the slot's frontier;
* the T new tokens' K/V (T=1 decode, T=k+1 speculative verify — one
  body, two grid shapes) are set-scattered into their tail pages by
  in-kernel DMA on the input/output-aliased pool, then attended straight
  from VMEM (so the streamed prefix never needs a read-after-write of
  the pool).  Parked/stalled rows and positions at/beyond the virtual
  row route to the trash page exactly like the gather path's scatter.

Per slot per layer the streamed bytes are ``ceil(len/bs) * bs * bh-slice
* Dh * 2 * itemsize`` — O(len), independent of the table capacity MB —
vs the gather's fixed ``MB * bs * Hkv * Dh * 2 * itemsize``.

Mask contract (must mirror ``causal_window_mask`` + the gather's
routing, pinned by tests/test_paged_attention.py):

* streamed keys: ``kpos < position`` and, for ``window > 0``,
  ``qpos - kpos < window``; unmapped table entries read page 0 exactly
  like the gather's ``where(tbl >= 0, tbl, 0)`` routing (the allocator
  guarantees pages below the frontier are mapped);
* new-token keys: ``kpos <= qpos``, ``kpos < virtual`` (tokens written
  to the trash page are not readable) and the window;
* rows parked at/beyond the virtual length stream nothing; their output
  is a uniform average of the new tokens (all-masked online softmax) —
  junk the engine discards, where the gather path computes whole-table
  garbage junk instead.  The other out-of-contract divergence: a row
  whose WRITE page is unmapped below the virtual frontier attends its
  real new token here, while the gather re-reads the stale routed-page
  value (its write went to trash).  The engine never decodes such a row
  — ``_ensure_blocks`` parks it — so in-contract streams are identical.

Routing lives in ``ops.paged_attn_route`` (counters + budget), block
sizes in :func:`pick_block` / the ``autotune.py`` ``paged_attn``
direction; the VMEM budget here is per-CHUNK, not per-table, so any
sequence length fits once ``(page_chunk, head_block)`` does.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.acdc_cascade_fused import VMEM_BUDGET

#: page-chunk candidates (pages DMA'd per streaming round), largest first
PAGE_CHUNKS = (8, 4, 2, 1)
#: KV-head row-block candidates, largest first (clamped to divisors of
#: the model's Hkv at the call site)
HEAD_BLOCKS = (8, 4, 2, 1)
#: deterministic off-device answer, pre-clamp
DEFAULT_BLOCK = (4, 4)

#: force the fused kernel even off-TPU (interpret mode) — parity tests
#: and benches flip this; default routing sends CPU runs to the gather
#: fallback (interpret-mode DMA walks are correctness-only).
FORCE_FUSED = os.environ.get("REPRO_PAGED_ATTN", "").lower() in (
    "fused", "force", "1")


def encode_block(blk: Tuple[int, int]) -> int:
    """Pack (page_chunk, head_block) into the autotune cache's int slot."""
    pc, bh = blk
    return pc * 256 + bh


def decode_block(enc: int) -> Tuple[int, int]:
    return enc // 256, enc % 256


def paged_attn_vmem_bytes(*, bs: int, dh: int, group: int, t: int,
                          pc: int, bh: int, itemsize: int) -> int:
    """Per-program VMEM footprint: chunk buffers + fp32 softmax state.

    Per-CHUNK, not per-table: the streamed K/V lives in a
    ``(pc, bs, bh, dh)`` double slot reused every round, so table
    capacity MB never enters the budget.
    """
    stream = 2 * pc * bs * bh * dh * itemsize          # k + v chunk bufs
    q = t * bh * group * dh * 4                        # fp32 query tile
    state = bh * group * t * (dh + 2) * 4              # acc + m + l, fp32
    newkv = 2 * t * bh * dh * itemsize                 # new-token K/V
    out = t * bh * group * dh * itemsize
    return stream + q + state + newkv + out


def pick_block(*, hkv: int, dh: int, group: int, t: int, bs: int,
               itemsize: int) -> Optional[Tuple[int, int]]:
    """Largest in-budget (page_chunk, head_block), or None if nothing
    fits (the dispatcher then keeps the gather fallback)."""
    for pc in PAGE_CHUNKS:
        for bh in HEAD_BLOCKS:
            if hkv % bh:
                continue
            if paged_attn_vmem_bytes(bs=bs, dh=dh, group=group, t=t,
                                     pc=pc, bh=bh,
                                     itemsize=itemsize) <= VMEM_BUDGET:
                return pc, bh
    return None


def clamp_block(blk: Tuple[int, int], *, hkv: int, dh: int, group: int,
                t: int, bs: int, itemsize: int) -> Optional[Tuple[int, int]]:
    """Fit an autotuned/default (pc, bh) to this call site: bh must
    divide Hkv and the pair must be in budget; degrade toward
    :func:`pick_block`'s answer rather than fail."""
    pc, bh = blk
    bh = min(bh, hkv)
    while bh > 1 and hkv % bh:
        bh -= 1
    if paged_attn_vmem_bytes(bs=bs, dh=dh, group=group, t=t, pc=pc, bh=bh,
                             itemsize=itemsize) <= VMEM_BUDGET:
        return pc, bh
    return pick_block(hkv=hkv, dh=dh, group=group, t=t, bs=bs,
                      itemsize=itemsize)


def _kernel(virtual, t, bs, pc, bh, group, dh, softcap,
            routed_r, pos_r, start_r, nch_r, phys_r, off_r, win_r,
            q_ref, kn_ref, vn_ref, kp_hbm, vp_hbm,
            o_ref, kp_out, vp_out, kbuf, vbuf, sem_k, sem_v, sem_s):
    i = pl.program_id(0)
    hb = pl.program_id(1)
    h0 = hb * bh

    # -- 1. persist the T new tokens' K/V head-slice into their (already
    #    trash-routed) tail pages.  Disjoint from every streamed read
    #    (reads stop at kpos < position), so no ordering hazard.
    for tt in range(t):
        page = phys_r[i, tt]
        o = off_r[i, tt]
        ck = pltpu.make_async_copy(
            kn_ref.at[tt], kp_out.at[page, o, pl.ds(h0, bh)], sem_s.at[0])
        cv = pltpu.make_async_copy(
            vn_ref.at[tt], vp_out.at[page, o, pl.ds(h0, bh)], sem_s.at[1])
        ck.start()
        cv.start()
        ck.wait()
        cv.wait()

    # -- 2. online softmax over the streamed prefix + the new tokens.
    q = q_ref[...].astype(jnp.float32)                 # (t, bh, group, dh)
    scale = dh ** -0.5
    pos_i = pos_r[i]
    win = win_r[0]
    qp = pos_i + jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)  # (t, 1)

    def fold(carry, kc, vc, msk):
        """One chunk of keys into the running (m, l, acc) state.
        kc/vc: (kk, bh, dh); msk: (t, kk), True = attend."""
        m, l, acc = carry
        s = jnp.einsum("thgd,khd->hgtk", q, kc.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(msk[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "hgtk,khd->hgtd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    def chunk(ci, carry):
        base = ci * pc
        for jj in range(pc):                           # static unroll
            page = routed_r[i, base + jj]
            pltpu.make_async_copy(kp_hbm.at[page, :, pl.ds(h0, bh)],
                                  kbuf.at[jj], sem_k.at[jj]).start()
            pltpu.make_async_copy(vp_hbm.at[page, :, pl.ds(h0, bh)],
                                  vbuf.at[jj], sem_v.at[jj]).start()
        for jj in range(pc):
            page = routed_r[i, base + jj]
            pltpu.make_async_copy(kp_hbm.at[page, :, pl.ds(h0, bh)],
                                  kbuf.at[jj], sem_k.at[jj]).wait()
            pltpu.make_async_copy(vp_hbm.at[page, :, pl.ds(h0, bh)],
                                  vbuf.at[jj], sem_v.at[jj]).wait()
        kc = kbuf[...].reshape(pc * bs, bh, dh)
        vc = vbuf[...].reshape(pc * bs, bh, dh)
        kpos = base * bs + jax.lax.broadcasted_iota(
            jnp.int32, (1, pc * bs), 1)                # (1, kk)
        msk = kpos < pos_i                             # streamed = prefix
        inw = jnp.where(win > 0, qp - kpos < win, True)
        return fold(carry, kc, vc, jnp.logical_and(msk, inw))

    m0 = jnp.full((bh, group, t), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bh, group, t), jnp.float32)
    a0 = jnp.zeros((bh, group, t, dh), jnp.float32)
    start_i = start_r[i]
    carry = jax.lax.fori_loop(start_i, start_i + nch_r[i], chunk,
                              (m0, l0, a0))

    # new tokens attend each other straight from VMEM (same values the
    # scatter just wrote), under the exact gather-path mask
    knpos = pos_i + jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    msk = jnp.logical_and(knpos <= qp, knpos < virtual)
    inw = jnp.where(win > 0, qp - knpos < win, True)
    m, l, acc = fold(carry, kn_ref[...], vn_ref[...],
                     jnp.logical_and(msk, inw))

    out = acc / jnp.maximum(l[..., None], 1e-30)       # (bh, group, t, dh)
    o_ref[...] = out.transpose(2, 0, 1, 3).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,                   # (B, T, Hq, Dh) post-RoPE queries
    knew: jax.Array,                # (B, T, Hkv, Dh) post-RoPE new keys
    vnew: jax.Array,                # (B, T, Hkv, Dh) new values
    k_pages: jax.Array,             # (NB+1, bs, Hkv, Dh) this layer's pool
    v_pages: jax.Array,
    block_tables: jax.Array,        # (B, MB) int32, -1 = unmapped
    position: jax.Array,            # (B,) first write index per row
    window: jax.Array,              # traced int32 scalar, 0 = global
    *,
    softcap: float,
    page_chunk: int,
    head_block: int,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused decode/verify attention against the paged pool.

    Returns ``(out (B, T, Hq, Dh), k_pages, v_pages)`` with the T new
    tokens' K/V scattered into the (aliased, in-place) pools — drop-in
    for the scatter+gather+SDPA sequence in ``models/attention.py``.
    """
    b, t, hq, dh = q.shape
    hkv = knew.shape[2]
    group = hq // hkv
    n_pages, bs = k_pages.shape[0], k_pages.shape[1]
    mb = block_tables.shape[1]
    virtual = mb * bs
    pc, bh = page_chunk, head_block
    if hkv % bh:
        raise ValueError(f"head_block {bh} must divide n_kv_heads {hkv}")

    # scalar-prefetch operands (SMEM): the routed table, per-row chunk
    # range, and the pre-routed scatter targets
    routed = jnp.where(block_tables >= 0, block_tables, 0).astype(jnp.int32)
    mbp = -(-mb // pc) * pc
    if mbp > mb:
        routed = jnp.pad(routed, ((0, 0), (0, mbp - mb)))
    pos = position.astype(jnp.int32)
    qpos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]   # (B,T)
    blk_idx = jnp.minimum(qpos // bs, mb - 1)
    phys = jnp.take_along_axis(block_tables, blk_idx, axis=1)
    writable = jnp.logical_and(phys >= 0, qpos < virtual)
    phys = jnp.where(writable, phys, n_pages - 1).astype(jnp.int32)
    off = (qpos % bs).astype(jnp.int32)
    win = jnp.reshape(window, (1,)).astype(jnp.int32)
    span = bs * pc
    frontier = jnp.minimum(pos, virtual)
    start = jnp.where(win[0] > 0,
                      jnp.maximum(pos - win[0] + 1, 0) // span,
                      0).astype(jnp.int32)
    nch = jnp.maximum((frontier + span - 1) // span - start, 0)
    # parked rows (pos >= virtual) stream nothing — their (discarded)
    # output costs zero pool bytes; this is where the O(len) claim comes
    # from for an idle slot
    nch = jnp.where(pos >= virtual, 0, nch).astype(jnp.int32)

    qg = q.reshape(b, t, hkv, group, dh)
    kernel = functools.partial(_kernel, virtual, t, bs, pc, bh, group, dh,
                               float(softcap))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(b, hkv // bh),
        in_specs=[
            pl.BlockSpec((None, t, bh, group, dh),
                         lambda i, j, *_: (i, 0, j, 0, 0)),
            pl.BlockSpec((None, t, bh, dh), lambda i, j, *_: (i, 0, j, 0)),
            pl.BlockSpec((None, t, bh, dh), lambda i, j, *_: (i, 0, j, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((None, t, bh, group, dh),
                         lambda i, j, *_: (i, 0, j, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((pc, bs, bh, dh), k_pages.dtype),
            pltpu.VMEM((pc, bs, bh, dh), v_pages.dtype),
            pltpu.SemaphoreType.DMA((pc,)),
            pltpu.SemaphoreType.DMA((pc,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out, kp, vp = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, t, hkv, group, dh), q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # operand indices count the 7 scalar-prefetch args: the pools are
        # operands 10/11 and alias outputs 1/2 (in-place update)
        input_output_aliases={10: 1, 11: 2},
        interpret=interpret,
    )(routed, pos, start, nch, phys, off, win,
      qg, knew.astype(k_pages.dtype), vnew.astype(v_pages.dtype),
      k_pages, v_pages)
    return out.reshape(b, t, hq, dh), kp, vp
