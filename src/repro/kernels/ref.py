"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated (interpret mode on CPU, compiled
on TPU) against the functions here with ``assert_allclose`` over shape and
dtype sweeps — see ``tests/test_kernels.py``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import transforms


def acdc_fused_ref(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Oracle for the fused ACDC kernel: ``y = ((x*a) C * d + bias) C^T``.

    Computed with the explicit orthonormal DCT matrix in float32.
    """
    n = x.shape[-1]
    c = transforms.dct_matrix(n, dtype=jnp.float32)
    h = (x.astype(jnp.float32) * a.astype(jnp.float32)) @ c
    h = h * d.astype(jnp.float32)
    if bias is not None:
        h = h + bias.astype(jnp.float32)
    y = h @ c.T
    return y.astype(x.dtype)


def scaled_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    pre: Optional[jax.Array] = None,
    post: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Oracle for the blocked scaled matmul: ``y = ((x*pre) @ w) * post + bias``."""
    h = x.astype(jnp.float32)
    if pre is not None:
        h = h * pre.astype(jnp.float32)
    y = h @ w.astype(jnp.float32)
    if post is not None:
        y = y * post.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)
