"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated (interpret mode on CPU, compiled
on TPU) against the functions here with ``assert_allclose`` over shape and
dtype sweeps — see ``tests/test_kernels.py``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import transforms


def acdc_fused_ref(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Oracle for the fused ACDC kernel: ``y = ((x*a) C * d + bias) C^T``.

    Computed with the explicit orthonormal DCT matrix in float32.
    """
    n = x.shape[-1]
    c = transforms.dct_matrix(n, dtype=jnp.float32)
    h = (x.astype(jnp.float32) * a.astype(jnp.float32)) @ c
    h = h * d.astype(jnp.float32)
    if bias is not None:
        h = h + bias.astype(jnp.float32)
    y = h @ c.T
    return y.astype(x.dtype)


def acdc_bwd_ref(
    x: jax.Array,
    a: jax.Array,
    d: jax.Array,
    g: jax.Array,
):
    """Oracle for the fused backward (paper eqs. 10-14), pure jnp fp32.

    Returns ``(dx, da, dd, dbias)`` — the same contract as
    ``kernels.acdc_bwd``: ``dx`` in ``x.dtype``, diagonal grads fp32.
    This is the four-matmul formulation the Pallas kernel replaced; it
    stays here purely as the test oracle.
    """
    n = x.shape[-1]
    c = transforms.dct_matrix(n, dtype=jnp.float32)
    x2 = x.reshape(-1, n).astype(jnp.float32)
    g2 = g.reshape(-1, n).astype(jnp.float32)
    gc = g2 @ c
    h2 = (x2 * a.astype(jnp.float32)) @ c
    dd = jnp.sum(h2 * gc, axis=0)
    dbias = jnp.sum(gc, axis=0)
    dh1 = (gc * d.astype(jnp.float32)) @ c.T
    da = jnp.sum(x2 * dh1, axis=0)
    dx = (a.astype(jnp.float32) * dh1).astype(x.dtype).reshape(x.shape)
    return dx, da, dd, dbias


def scaled_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    pre: Optional[jax.Array] = None,
    post: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Oracle for the blocked scaled matmul: ``y = ((x*pre) @ w) * post + bias``."""
    h = x.astype(jnp.float32)
    if pre is not None:
        h = h * pre.astype(jnp.float32)
    y = h @ w.astype(jnp.float32)
    if post is not None:
        y = y * post.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)
