"""Blocked scaled-matmul Pallas kernel — the "multiple call" building block.

Computes ``y = ((x * pre) @ w) * post + bias`` with a standard (m, n, k)
grid, fp32 VMEM accumulator scratch, and the diagonal scalings fused into
the k-loop so they cost no extra HBM traffic.

Two chained calls (w = C then w = C^T) implement ACDC for sizes where the
fully-fused kernel's VMEM budget is exceeded — the TPU analogue of the
paper's cuFFT-based multiple-call implementation (section 5.2), but with
the diagonal scalings folded in, so the intermediate ``h2`` round-trips HBM
exactly once instead of three extra round trips for A, D and the bias.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BN = 512
DEFAULT_BK = 512


def _smm_kernel(sig, nk, x_ref, w_ref, *rest):
    """Grid (m, n, k): accumulate (x*pre)[m,k] @ w[k,n] into VMEM scratch,
    finalize with post-scale and bias on the last k step."""
    refs = dict(zip(sig, rest))
    o_ref = rest[len(sig)]
    acc_ref = rest[len(sig) + 1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    if "pre" in refs:
        x = x * refs["pre"][...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finalize():
        acc = acc_ref[...]
        if "post" in refs:
            acc = acc * refs["post"][...].astype(jnp.float32)
        if "bias" in refs:
            acc = acc + refs["bias"][...].astype(jnp.float32)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def scaled_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    pre: Optional[jax.Array] = None,
    post: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """``((x * pre) @ w) * post + bias`` for 2-D x (M, K), w (K, N)."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    bm = min(bm, max(8, m))
    bn = min(bn, n)
    bk = min(bk, kdim)
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-kdim) % bk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    mm, kk = x.shape
    nn = w.shape[1]
    nk = kk // bk
    grid = (mm // bm, nn // bn, nk)

    operands = [x, w]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    sig = []
    if pre is not None:
        if pad_k:
            pre = jnp.pad(pre, ((0, pad_k),))
        operands.append(pre.reshape(1, kk))
        in_specs.append(pl.BlockSpec((1, bk), lambda i, j, k: (0, k)))
        sig.append("pre")
    if post is not None:
        if pad_n:
            post = jnp.pad(post, ((0, pad_n),))
        operands.append(post.reshape(1, nn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        sig.append("post")
    if bias is not None:
        if pad_n:
            bias = jnp.pad(bias, ((0, pad_n),))
        operands.append(bias.reshape(1, nn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        sig.append("bias")

    kernel = functools.partial(_smm_kernel, tuple(sig), nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
    if pad_m or pad_n:
        out = out[:m, :n]
    return out
