import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (including
# jax): the host-platform device count locks on first jax init.  They give
# this CPU-only container 512 placeholder devices so the production meshes
# (16x16 single-pod, 2x16x16 multi-pod) can be built and every
# (architecture x input-shape) cell can be .lower().compile()'d for real.

"""Multi-pod dry-run driver.

For every (arch x shape) cell and mesh:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(*input ShapeDtypeStructs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes scrape

Results stream into ``results/dryrun/<cell>.json`` so interrupted sweeps
resume where they stopped.  Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_1_7b \
        --shape train_4k [--multi-pod] [--all] [--force]
"""

import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist import sharding as shard_mod
from repro.dist import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.optim import OptimizerConfig, cosine_schedule, make_optimizer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string like 'bf16[128,1024]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (output-shape sized)."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.  %all-reduce.5 = bf16[8192,2752]{1,0} all-reduce(...)
        m = re.match(r"%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        if op.rstrip("-start").rstrip("-done") in _COLLECTIVES or op in _COLLECTIVES:
            key = op.replace("-start", "").replace("-done", "")
            if key in out:
                out[key] += _shape_bytes(m.group(1))
                count[key] += 1
    return {"bytes": out, "count": count,
            "total_bytes": int(sum(out.values()))}


# ---------------------------------------------------------------------------
# HLO-text analysis: XLA's compiled.cost_analysis() on the CPU backend does
# not include dots inside fused/called computations, so FLOPs and bytes are
# derived by walking the optimized HLO text instead (the numbers then come
# from the actual compiled schedule).
# ---------------------------------------------------------------------------

_DOT_RE = re.compile(
    r"= \S+ dot\((.*?)\)(?:.*?lhs_contracting_dims=\{([\d,]*)\})?"
)
_OP_RE = re.compile(r"^\s*(?:ROOT )?%?[\w.\-]+ = (\S+\[[\d,]*\]\S*) ([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_SHAPES_IN_LINE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_elems(dt: str, dims: str):
    if dt not in _DTYPE_BYTES:
        return None
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


_DEF_RE = re.compile(r"^\s*(?:ROOT )?(%[\w.\-]+) = (\w+)\[([\d,]*)\]")


def hlo_text_analysis(hlo_text: str) -> dict:
    """Walk every computation in the optimized HLO.

    * flops: 2 * out_elems * contraction for every ``dot`` anywhere
      (fusion bodies included — that is where the real matmuls live).
      Operand shapes are not printed inline in optimized HLO, so a first
      pass builds a name -> shape map (per computation, global fallback).
    * bytes: for every op OUTSIDE fused-computation bodies (kernel
      boundaries), output bytes + operand bytes — a fusion-boundary HBM
      traffic estimate;
    * while bodies are counted once: callers unroll layer scans first
      (see benchmarks/roofline.py).
    """
    lines = hlo_text.splitlines()
    # pass 1: op name -> (dtype, dims) per computation + global
    comp = "entry"
    shapes_global: dict = {}
    shapes_by_comp: dict = {}
    for raw in lines:
        mcomp = _COMP_RE.match(raw)
        if mcomp:
            comp = mcomp.group(1)
            continue
        m = _DEF_RE.match(raw)
        if m:
            name, dt, dims = m.group(1), m.group(2), m.group(3)
            entry = (dt, dims)
            shapes_global[name] = entry
            shapes_by_comp.setdefault(comp, {})[name] = entry

    def lookup(comp_name, op_name):
        return (shapes_by_comp.get(comp_name, {}).get(op_name)
                or shapes_global.get(op_name))

    flops = 0.0
    bytes_ = 0.0
    comp = "entry"
    for raw in lines:
        mcomp = _COMP_RE.match(raw)
        if mcomp:
            comp = mcomp.group(1)
            continue
        s = raw.strip()
        if " dot(" in s or s.startswith("%dot") or " = " in s and " dot(" in s:
            md = _DEF_RE.match(raw)
            mo = re.search(r"dot\((%[\w.\-]+)(?:, (%[\w.\-]+))?\)", s)
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
            mb = re.search(r"lhs_batch_dims=\{([\d,]*)\}", s)
            if md and mo and mc:
                out_elems = _shape_elems(md.group(2), md.group(3))
                lhs_entry = lookup(comp, mo.group(1))
                contract = 1
                if lhs_entry is not None:
                    lhs_dims = [int(d) for d in lhs_entry[1].split(",") if d]
                    if mc.group(1):
                        for ci in mc.group(1).split(","):
                            ci = int(ci)
                            if ci < len(lhs_dims):
                                contract *= lhs_dims[ci]
                if out_elems is not None:
                    flops += 2.0 * out_elems * contract
        m = _OP_RE.match(raw)
        if m and not comp.startswith("fused_"):
            op = m.group(2)
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast"):
                continue
            # output bytes
            md = _DEF_RE.match(raw)
            if md:
                n = _shape_elems(md.group(2), md.group(3))
                if n is not None:
                    bytes_ += n * _DTYPE_BYTES[md.group(2)]
            # operand bytes via name lookup
            inner = s[s.index("(") + 1:] if "(" in s else ""
            for op_name in re.findall(r"(%[\w.\-]+)", inner):
                entry = lookup(comp, op_name)
                if entry is not None:
                    n = _shape_elems(entry[0], entry[1])
                    if n is not None:
                        bytes_ += n * _DTYPE_BYTES[entry[0]]
    return {"flops": flops, "bytes": bytes_}


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_cell(arch: str, shape_name: str, mesh, sell: str = "dense",
               accum_steps: int = 1, n_layers: int = 0,
               cfg_overrides: dict | None = None):
    """Returns (fn, args, in_shardings, out_shardings) ready to lower.

    ``n_layers`` > 0 overrides the layer count (and encoder depth for
    enc-dec archs) — used by the roofline module's two-point loop-count
    extrapolation (XLA cost_analysis counts while bodies once).
    """
    import dataclasses

    cfg = registry.get_config(arch)
    if sell != "dense":
        cfg = dataclasses.replace(cfg, sell_kind=sell)
    if n_layers:
        upd = {"n_layers": n_layers}
        if cfg.family == "encdec":
            upd["n_encoder_layers"] = n_layers
        cfg = dataclasses.replace(cfg, **upd)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if cfg.sell_kind != "dense" and not cfg.sell_batch_axes:
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        cfg = dataclasses.replace(cfg, sell_batch_axes=axes)
    shape = registry.get_shape(shape_name)
    model = get_model(cfg)
    rep = _replicated(mesh)

    specs = registry.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = make_optimizer(OptimizerConfig(kind="adamw"),
                             cosine_schedule(3e-4, 1000, 100_000))
        step_fn = steps_mod.make_train_step(model, cfg, opt,
                                            accum_steps=accum_steps)
        state_abs = steps_mod.abstract_state(model, cfg, opt)
        state_sh = shard_mod.param_shardings(state_abs, mesh)
        batch_abs = specs["batch"]
        batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shard_mod.data_specs(mesh, batch_abs))
        metrics_sh = {"loss": rep, "grad_norm": rep, "update_norm": rep}
        return (step_fn, (state_abs, batch_abs),
                (state_sh, batch_sh), (state_sh, metrics_sh))

    if shape.kind == "prefill":
        params_abs = jax.eval_shape(
            functools.partial(model.init, cfg=cfg), jax.random.PRNGKey(0))
        params_sh = shard_mod.param_shardings(params_abs, mesh)
        # the REAL serving prefill: full-prompt forward + decode-cache
        # scatter in one lowered program (repro.dist.steps.make_prefill_step)
        cache_abs = jax.eval_shape(
            functools.partial(model.init_cache, cfg, shape.global_batch,
                              shape.seq_len))
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shard_mod.cache_specs(cache_abs, mesh))
        tok = specs["tokens"]
        tok_sh = NamedSharding(mesh, shard_mod.data_specs(mesh, tok))
        lengths = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        step_fn = steps_mod.make_prefill_step(model, cfg, full_logits=True)
        args = [params_abs, cache_abs, tok, lengths]
        in_sh = [params_sh, cache_sh, tok_sh, rep]
        fe = specs.get("frontend_embeds")
        if fe is not None:
            args.append(fe)
            in_sh.append(NamedSharding(mesh, shard_mod.data_specs(mesh, fe)))
        # logits: batch over (pod,data), vocab over model when divisible
        vspec = shard_mod.spec_for(mesh, (shape.global_batch, shape.seq_len,
                                          cfg.vocab_size),
                                   ("batch", None, "vocab"))
        out_sh = (NamedSharding(mesh, vspec), cache_sh)
        return step_fn, tuple(args), tuple(in_sh), out_sh

    if shape.kind == "decode":
        params_abs = jax.eval_shape(
            functools.partial(model.init, cfg=cfg), jax.random.PRNGKey(0))
        params_sh = shard_mod.param_shardings(params_abs, mesh)
        cache_abs = jax.eval_shape(
            functools.partial(model.init_cache, cfg, shape.global_batch,
                              shape.seq_len))
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shard_mod.cache_specs(cache_abs, mesh))
        serve = steps_mod.make_serve_step(model, cfg)
        tok, pos = specs["tokens"], specs["position"]

        def decode(params, cache, tokens, position):
            return serve(params, cache, tokens, position,
                         jax.random.PRNGKey(0))

        args = (params_abs, cache_abs, tok, pos)
        in_sh = (params_sh, cache_sh, rep, rep)
        out_sh = (rep, cache_sh)
        return decode, args, in_sh, out_sh

    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sell: str = "dense", save: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}.{shape_name}.{mesh_name}" + (
        "" if sell == "dense" else f".{sell}")
    skip = registry.skips(arch, shape_name)
    if skip:
        rec = {"cell": cell_id, "status": "skipped", "reason": skip}
        if save:
            _save(cell_id, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh = build_cell(arch, shape_name, mesh, sell)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):     # older jax: list of dicts
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_dev = mesh.devices.size
        rec = {
            "cell": cell_id,
            "status": "ok",
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "sell": sell,
            "n_devices": int(n_dev),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": _mem_dict(mem),
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)),
            "collectives": coll,
        }
    except Exception as e:  # noqa: BLE001 — a failed cell is a system bug
        rec = {"cell": cell_id, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    if save:
        _save(cell_id, rec)
    return rec


def _mem_dict(mem) -> dict:
    keys = ("temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _save(cell_id: str, rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=registry.ARCHS)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in registry.SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--sell", default="dense",
                    help="SELL kind for projections (dense|acdc|...)")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have results")
    args = ap.parse_args()

    if args.all:
        cells = registry.cells(include_skipped=True)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            suffix = "" if args.sell == "dense" else f".{args.sell}"
            path = os.path.join(
                RESULTS_DIR, f"{arch}.{shape}.{mesh_name}{suffix}.json")
            if not args.force and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip-cached] {arch}.{shape}.{mesh_name}")
                    continue
            t0 = time.time()
            rec = run_cell(arch, shape, mp, sell=args.sell)
            status = rec["status"]
            extra = ""
            if status == "ok":
                gib = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
                extra = (f" args={gib:.2f}GiB/dev "
                         f"flops={rec['flops_per_device']:.3g} "
                         f"coll={rec['collectives']['total_bytes']/2**30:.3f}GiB "
                         f"({time.time()-t0:.0f}s)")
            elif status == "error":
                extra = " " + rec["error"][:200]
            print(f"[{status}] {arch}.{shape}.{mesh_name}{extra}", flush=True)


if __name__ == "__main__":
    main()
