"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required for the dry-run's
512-placeholder-device trick to work (device count locks on first use).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the leading "pod"
    axis maps onto the slow inter-pod (DCN/ICI-bridge) links and only ever
    carries data-parallel gradient traffic (and optionally compressed —
    see repro/dist/compression.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over however many local devices exist (tests/CPU)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
