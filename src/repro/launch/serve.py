"""Serving launcher: continuous-batching engine over the model zoo.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --smoke \
        --slots 4 --prompt-len 32 --gen 32 --requests 12

Each request gets a random ragged-length prompt; the engine admits them
into batch slots (one lowered prefill program per admission), advances all
active slots with one fused decode step per tick, and evicts finished
requests so the batch stays full.  ``--static`` falls back to plain
batched prefill + lockstep decode (no continuous batching) for A/B runs.
``--paged --block-size 16 [--blocks N]`` serves from the paged block KV
cache: all slots draw pages from one global pool sized for the traffic
mix instead of each reserving a dense ``max_len`` slab.
``--spec [--spec-k 4] [--draft-depth K/2] [--spec-skip-layers J]`` turns
on speculative decoding: the target's own truncated ACDC cascades draft
``spec-k`` tokens per tick and one verify program scores them all, so
each slot advances by its accepted length per target dispatch (greedy
streams are bit-identical to the non-speculative engine).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.dist import steps as steps_mod
from repro.models import get_model
from repro.obs import (
    REGISTRY,
    JsonlExporter,
    Observability,
    Prof,
    ProfileWindow,
    Registry,
    SpanTracer,
    set_global_tracer,
)
from repro.serving import Engine
from repro.serving.request import make_ragged_requests


def _make_frontend(cfg, rng, batch: int):
    if cfg.family == "encdec":
        frames = cfg.n_frontend_tokens or 16
        return jax.random.normal(rng, (batch, frames, cfg.d_model))
    return None


def run_static(model, cfg, params, args, prompts, rng):
    """Batched prefill then lockstep greedy decode (no slot reuse)."""
    b, p, g = args.slots, args.prompt_len, args.gen
    max_len = p + g + 1
    cache = model.init_cache(cfg, b, max_len)
    fe = _make_frontend(cfg, rng, b)
    prefill = jax.jit(steps_mod.make_prefill_step(model, cfg))
    serve = jax.jit(steps_mod.make_serve_step(
        model, cfg, sample=args.sample, temperature=args.temperature,
        top_k=args.top_k, top_p=args.top_p))

    from repro.serving import sampler as sampler_mod

    t0 = time.time()
    lengths = jnp.full((b,), p, jnp.int32)
    last, cache = prefill(params, cache, prompts, lengths, fe)
    tok = sampler_mod.sample(rng, last, method=args.sample,
                             temperature=args.temperature,
                             top_k=args.top_k, top_p=args.top_p)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(g - 1):
        pos = jnp.full((b,), p + i, jnp.int32)
        tok, cache = serve(params, cache, tok, pos,
                           jax.random.fold_in(rng, i))
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"[static] prefill {p}x{b} toks in ONE dispatch: {t_prefill:.2f}s | "
          f"decode {g - 1} steps: {dt:.2f}s ({b * (g - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[: min(b, 2)]:
        print("  ", row[:16].tolist())


def build_obs(args) -> Observability:
    """Assemble the observability bundle from the launcher flags.

    With no obs flags set this returns ``Observability.off()`` — the
    engine's documented noop fast path (see ``repro/obs/__init__.py``).
    The engine owns the per-engine registry built here; the JSON-lines
    exporter merges in the process-global ``REGISTRY`` snapshot so the
    kernels' trace-time dispatch counters ride along.
    """
    if not (args.metrics_jsonl or args.trace_out or args.profile_ticks):
        return Observability.off()
    reg = Registry()
    tracer = None
    if args.trace_out:
        # clock=None: the tracer adopts the engine's clock at attach
        tracer = SpanTracer()
        set_global_tracer(tracer)
    exporter = None
    if args.metrics_jsonl:
        exporter = JsonlExporter(args.metrics_jsonl, reg,
                                 every=args.metrics_every,
                                 clock=time.time,
                                 extra_snapshots=(REGISTRY.snapshot,))
    window = None
    prof = None
    if args.profile_ticks:
        window = ProfileWindow(args.profile_ticks, args.profile_logdir)
        prof = Prof(enabled=True)
    return Observability(registry=reg, tracer=tracer, exporter=exporter,
                         prof=prof, window=window)


def run_engine(model, cfg, params, args, rng):
    obs = build_obs(args)
    eng = Engine(model, cfg, params, n_slots=args.slots,
                 max_len=args.prompt_len + args.gen + 1,
                 max_prompt_len=args.prompt_len, sample=args.sample,
                 temperature=args.temperature, top_k=args.top_k,
                 top_p=args.top_p, paged=args.paged,
                 block_size=args.block_size, n_blocks=args.blocks,
                 spec_k=args.spec_k if args.spec else 0,
                 draft_depth=args.draft_depth,
                 draft_skip_layers=args.spec_skip_layers,
                 obs=obs)
    if args.spec:
        print(f"[spec] k={eng.spec_k} draft={type(eng.draft).__name__} "
              f"depth={getattr(eng.draft, 'depth', '-')} "
              f"skip_layers={getattr(eng.draft, 'skip_layers', 0)}")
    if args.paged:
        print(f"[paged] block_size={eng.block_size} "
              f"pool={eng.allocator.n_blocks} blocks "
              f"(dense parity {args.slots * eng.max_blocks}) | "
              f"cache {eng.cache_bytes / 1e6:.2f} MB")
    deadline_range = None
    if args.deadline_s is not None:
        deadline_range = (args.deadline_s, args.deadline_s)
    reqs = make_ragged_requests(cfg.vocab_size, args.requests,
                                args.prompt_len, args.gen,
                                deadline_range=deadline_range,
                                deadline_frac=args.deadline_frac,
                                n_priorities=args.priorities)
    if cfg.family == "encdec":
        for req in reqs:
            req.frontend_embeds = _make_frontend(
                cfg, jax.random.fold_in(jax.random.PRNGKey(7), req.rid), 1)

    t0 = time.time()
    eng.run(reqs,
            max_ticks=4 * args.requests * (args.prompt_len + args.gen) + 64,
            wall_clock_limit_s=args.wall_clock_limit_s)
    dt = time.time() - t0
    if eng.wall_clock_exceeded:
        print(f"[engine] WALL CLOCK LIMIT ({args.wall_clock_limit_s}s) hit: "
              f"partial results")
    toks = eng.stats["tokens_out"]
    ttft = [r.t_first_token - r.t_submit for r in reqs
            if r.t_first_token is not None]
    print(f"[engine] {len(reqs)} ragged requests | "
          f"{eng.stats['prefill_dispatches']} prefill dispatches | "
          f"{eng.stats['decode_ticks']} decode ticks | "
          f"{toks} tokens in {dt:.2f}s ({toks / max(dt, 1e-9):.1f} tok/s)")
    if args.paged:
        print(f"[paged] peak {eng.allocator.peak_in_use}/"
              f"{eng.allocator.n_blocks} blocks in use | "
              f"{eng.stats['stalled_slot_ticks']} stalled slot-ticks | "
              f"{eng.stats['preempted']} preempted")
    s = eng.stats
    if (s["requeued"] or s["timeout"] or s["rejected"]
            or s["degrade_down"]):
        print(f"[resilience] {s['requeued']} requeued "
              f"({s['deadline_preempts']} for deadlines) | "
              f"{s['timeout']} timed out | {s['rejected']} shed | "
              f"ladder down/up {s['degrade_down']}/{s['degrade_up']} "
              f"(now {eng.degrade_level})")
    if args.spec:
        print(f"[spec] {eng.stats['accepted']}/{eng.stats['drafted']} "
              f"drafts accepted (rate "
              f"{eng.stats['acceptance_rate']:.3f}) | "
              f"{eng.stats['decode_ticks']} verify dispatches for "
              f"{toks} tokens "
              f"({toks / max(eng.stats['decode_ticks'], 1):.2f} tok/dispatch)")
    if ttft:
        print(f"[engine] ttft p50 {np.median(ttft):.3f}s "
              f"max {max(ttft):.3f}s")
    print("sample generations (token ids):")
    for r in reqs[:2]:
        print(f"   rid={r.rid} len={r.prompt_len} "
              f"finish={r.finish_reason}: {r.generated[:16]}")

    obs.close()
    if obs.tracer is not None:
        obs.tracer.write(args.trace_out)
        print(f"[obs] chrome trace -> {args.trace_out} "
              f"(load in chrome://tracing or ui.perfetto.dev)")
    if obs.exporter is not None:
        print(f"[obs] metrics jsonl -> {args.metrics_jsonl} "
              f"({obs.exporter.exports} snapshots)")
    if obs.window is not None:
        print(f"[obs] profiler capture -> {args.profile_logdir} "
              f"(ticks {args.profile_ticks})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b", choices=registry.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sell", default="dense")
    ap.add_argument("--sell-method", default="auto",
                    choices=["auto", "fft", "matmul", "pallas"],
                    help="transform backend for SELL projections")
    ap.add_argument("--sell-transform", default="acdc",
                    help="transform family for --sell acdc cascades "
                         "(core/families.py: acdc | circulant | hadamard)")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temp"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--static", action="store_true",
                    help="batched prefill + lockstep decode, no slot reuse")
    ap.add_argument("--paged", action="store_true",
                    help="paged block KV cache: slots draw fixed-size pages "
                         "from one global pool instead of each reserving "
                         "a dense max_len slab")
    ap.add_argument("--block-size", type=int, default=16,
                    help="token positions per KV page (paged mode)")
    ap.add_argument("--blocks", type=int, default=None,
                    help="pool size in pages; default = dense parity "
                         "(slots * ceil(max_len / block_size))")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding: truncated-cascade "
                         "self-draft + one batched k-token verify per tick")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative tick")
    ap.add_argument("--draft-depth", type=int, default=None,
                    help="cascade layers the draft keeps "
                         "(default sell_k // 2)")
    ap.add_argument("--spec-skip-layers", type=int, default=0,
                    help="also drop this many top transformer blocks "
                         "from the draft (decoder families)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="give a fraction of requests this latency SLO; "
                         "admission turns earliest-deadline-first and "
                         "requests past the deadline finish as timeouts")
    ap.add_argument("--deadline-frac", type=float, default=0.5,
                    help="fraction of requests carrying --deadline-s")
    ap.add_argument("--priorities", type=int, default=1,
                    help="priority bands drawn uniformly per request "
                         "(ties in deadline order; shed order under "
                         "overload)")
    ap.add_argument("--wall-clock-limit-s", type=float, default=None,
                    help="hard bound on the serve loop's real time; exits "
                         "with partial results instead of hanging")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append periodic registry snapshots (JSON lines) "
                         "to PATH; off when unset")
    ap.add_argument("--metrics-every", type=int, default=50,
                    help="ticks between --metrics-jsonl snapshots")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write per-request span tracing as Chrome "
                         "trace-event JSON to PATH; off when unset")
    ap.add_argument("--profile-ticks", default=None, metavar="A:B",
                    help="capture a jax.profiler trace across engine "
                         "ticks A..B inclusive (see --profile-logdir)")
    ap.add_argument("--profile-logdir", default="results/profile",
                    help="destination for the --profile-ticks capture")
    args = ap.parse_args(argv)
    if args.paged and args.static:
        ap.error("--paged applies to the engine path, not --static")
    if args.spec and args.static:
        ap.error("--spec applies to the engine path, not --static")
    if args.static and (args.metrics_jsonl or args.trace_out
                        or args.profile_ticks):
        ap.error("--metrics-jsonl/--trace-out/--profile-ticks apply to "
                 "the engine path, not --static")

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    cfg = registry.with_sell(cfg, args.sell, method=args.sell_method,
                             transform=args.sell_transform)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    print(f"arch={cfg.name} sell={cfg.sell_kind} slots={args.slots}")

    if args.static:
        prompts = jax.random.randint(
            rng, (args.slots, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
        run_static(model, cfg, params, args, prompts, rng)
    else:
        run_engine(model, cfg, params, args, rng)


if __name__ == "__main__":
    main()
