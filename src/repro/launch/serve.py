"""Batched serving launcher: prefill + decode loop with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.dist import steps as steps_mod
from repro.models import get_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b", choices=registry.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sell", default="dense")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temp"])
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if args.sell != "dense":
        cfg = dataclasses.replace(cfg, sell_kind=args.sell)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)

    b, p, g = args.batch, args.prompt_len, args.gen
    max_len = p + g + 1
    prompts = jax.random.randint(rng, (b, p), 0, cfg.vocab_size, jnp.int32)

    cache = model.init_cache(cfg, b, max_len)
    serve_step = jax.jit(
        steps_mod.make_serve_step(model, cfg, sample=args.sample),
        static_argnums=())

    # prefill: feed prompt tokens one step at a time through the decode path
    # (smoke-scale; the production prefill lowers model.apply — see dryrun
    # prefill cells).  For encdec archs the cross-KV prefill runs first.
    if cfg.family == "encdec":
        frames = jax.random.normal(
            rng, (b, cfg.n_frontend_tokens or 16, cfg.d_model))
        cache = model.module.prefill_cross(params, cache, frames, cfg)

    t0 = time.time()
    tok = prompts[:, 0]
    for i in range(p - 1):
        _, cache = serve_step(params, cache, tok,
                              jnp.full((b,), i, jnp.int32), rng)
        tok = prompts[:, i + 1]
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    for i in range(g):
        pos = jnp.full((b,), p - 1 + i, jnp.int32)
        tok, cache = serve_step(params, cache, tok, pos,
                                jax.random.fold_in(rng, i))
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"arch={cfg.name} sell={cfg.sell_kind} batch={b}")
    print(f"prefill {p} toks: {t_prefill:.2f}s | decode {g} steps: {dt:.2f}s "
          f"({b * g / dt:.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[: min(b, 2)]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
