"""Production training launcher.

Wires together: config -> model -> optimizer (paper lr-multiplier groups
for SELL diagonals) -> sharded train state -> pjit train step -> data
pipeline -> checkpoint manager (async, atomic, keep-k) -> elastic policy
(SIGTERM drain + straggler monitor).

Runs for real on whatever devices exist (CPU in this container, a pod on
the cluster — the same code path; only the mesh shape changes).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --smoke \
        --steps 20 --sell acdc
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import DataConfig, SyntheticLM
from repro.dist import compression, elastic, sharding as shard_mod, \
    steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.obs import REGISTRY, JsonlExporter
from repro.optim import (OptimizerConfig, cosine_schedule, make_optimizer,
                         tree_paths)

# The paper's per-group treatment of the SELL diagonals (section 6.2):
# lr x24 on A, x12 on D, no weight decay on either; norms/bias undecayed.
SELL_GROUPS = (
    (r"sell/a$", {"lr_mult": 24.0, "weight_decay": 0.0}),
    (r"sell/d$", {"lr_mult": 12.0, "weight_decay": 0.0}),
    (r"sell/", {"weight_decay": 0.0}),
    (r"norm|scale$|bias$", {"weight_decay": 0.0}),
)


def build(arch: str, smoke: bool, sell: str, seq_len: int,
          global_batch: int, lr: float, total_steps: int,
          accum_steps: int = 1, mesh=None, compress_grads: bool = False,
          sell_method: str = "auto", sell_transform: str = "acdc"):
    cfg = registry.get_smoke_config(arch) if smoke else registry.get_config(arch)
    cfg = registry.with_sell(cfg, sell, method=sell_method,
                             transform=sell_transform)
    model = get_model(cfg)
    opt = make_optimizer(
        OptimizerConfig(kind="adamw", lr=lr, groups=SELL_GROUPS),
        cosine_schedule(lr, max(total_steps // 20, 1), total_steps))
    mesh = mesh or make_host_mesh()
    if compress_grads and dict(mesh.shape).get("model", 1) > 1:
        # the compressed shard_map treats params as replicated across the
        # whole mesh; on a model-parallel mesh that would silently
        # all-gather the full param tree onto every device
        raise ValueError("--compress-grads supports data-parallel meshes "
                         "only (model axis must be 1)")
    compress_dp = dict(mesh.shape)["data"] if compress_grads else 0
    train_step = steps_mod.make_train_step(
        model, cfg, opt, accum_steps,
        compress_mesh=mesh if compress_grads else None)

    state_abs = steps_mod.abstract_state(model, cfg, opt,
                                         compress_dp=compress_dp)
    state_sh = shard_mod.param_shardings(state_abs, mesh)
    if compress_grads:
        # per-rank residuals live on their rank: leading axis over "data"
        state_sh["grad_error"] = jax.tree.map(
            lambda _: NamedSharding(mesh, P("data")),
            state_abs["grad_error"])

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        frontend=cfg.frontend,
        n_frontend_tokens=(cfg.n_frontend_tokens
                           or (seq_len // 4 if cfg.frontend == "audio" else 0)),
        d_model=cfg.d_model,
    )
    pipeline = SyntheticLM(data_cfg)
    batch_abs = jax.eval_shape(pipeline.batch_at, 0)
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shard_mod.data_specs(mesh, batch_abs))
    rep = NamedSharding(mesh, jax.sharding.PartitionSpec())
    metrics_sh = {"loss": rep, "grad_norm": rep, "update_norm": rep}

    jitted = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh),
                     donate_argnums=(0,))
    return cfg, model, opt, mesh, jitted, pipeline, state_sh


def _train_metrics():
    """Training diagnostics in the process-global registry (names are
    documented in the ``repro/obs/__init__.py`` glossary)."""
    return {
        "loss": REGISTRY.gauge("train_step_loss", "last step loss"),
        "tps": REGISTRY.gauge("train_tokens_per_s",
                              "last step token throughput"),
        "step_s": REGISTRY.histogram("train_step_seconds",
                                     "step wall time (incl. compile on "
                                     "the first step)"),
        "wire": REGISTRY.gauge("train_grad_compressed_bytes",
                               "int8+scales gradient wire bytes per "
                               "all-reduce"),
        "raw": REGISTRY.gauge("train_grad_raw_bytes",
                              "fp32-equivalent gradient bytes per "
                              "all-reduce"),
        "diag": REGISTRY.gauge("train_cascade_diag_norm",
                               "per-cascade SELL diagonal l2 norm",
                               labels=("param", "cascade")),
    }


def _grad_wire_bytes(params):
    """Static per-all-reduce payload of the int8 blockwise compressor
    (int8 payload padded to BLOCK plus one fp32 scale per block) vs the
    uncompressed fp32 equivalent."""
    wire = raw = 0
    for leaf in jax.tree.leaves(params):
        n = max(int(np.prod(leaf.shape)), 1)
        nb = -(-n // compression.BLOCK)
        wire += nb * compression.BLOCK + 4 * nb
        raw += 4 * n
    return wire, raw


def _emit_diag_norms(gauge, params) -> None:
    """Per-cascade ||A||_2 / ||D||_2 gauges — the paper's init/depth
    sensitivity lives in how these diagonals move, so expose them per
    cascade (labeled by the param path) rather than as one global norm."""
    paths = jax.tree.leaves(tree_paths(params))
    for path, leaf in zip(paths, jax.tree.leaves(params)):
        for suffix in ("a", "d"):
            if path.endswith(f"sell/{suffix}"):
                cascade = path[: -len(f"/sell/{suffix}")]
                gauge.labels(param=suffix, cascade=cascade).set(
                    float(np.linalg.norm(np.asarray(leaf))))


def _restore(ckpt, step, model, cfg, opt, compress_dp, state_sh):
    """Elastic-safe restore: grad_error residuals are an optimization, not
    model state, so a checkpoint that lacks them (compression turned on
    after the save) or carries them for a different data-parallel size
    (elastic shrink/grow changed the rank axis) restores everything else
    and re-zeros the residuals instead of silently mis-sharding them."""
    state_abs = steps_mod.abstract_state(model, cfg, opt,
                                         compress_dp=compress_dp)
    try:
        state = ckpt.restore(step, state_abs, state_sh)
    except KeyError:
        if not compress_dp:
            raise
        base_abs = {k: v for k, v in state_abs.items() if k != "grad_error"}
        base_sh = {k: v for k, v in state_sh.items() if k != "grad_error"}
        state = ckpt.restore(step, base_abs, base_sh)
        state["grad_error"] = None
    if compress_dp:
        err = state.get("grad_error")
        lead = (jax.tree.leaves(err)[0].shape[0] if err is not None else None)
        if lead != compress_dp:
            print(f"[compress] residual rank axis {lead} -> {compress_dp}: "
                  f"resetting error feedback", flush=True)
            fresh = jax.tree.map(
                lambda p: jnp.zeros((compress_dp,) + tuple(p.shape),
                                    jnp.float32), state["params"])
            state["grad_error"] = jax.device_put(fresh,
                                                 state_sh["grad_error"])
    return state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b", choices=registry.ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--sell", default="dense")
    ap.add_argument("--sell-method", default="auto",
                    choices=["auto", "fft", "matmul", "pallas"],
                    help="transform backend for SELL projections; "
                         "'pallas' runs the fused whole-cascade kernel "
                         "(interpret mode off-TPU)")
    ap.add_argument("--sell-transform", default="acdc",
                    help="transform family for --sell acdc cascades "
                         "(core/families.py: acdc | circulant | hadamard)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append registry snapshots (JSON lines) to PATH "
                         "on the --log-every cadence; off when unset")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient all-reduce "
                         "(repro.dist.compression) over the data axis")
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="resolve the mesh via ElasticPolicy from however "
                         "many devices survived (elastic restart drill); "
                         "0 = plain host mesh")
    args = ap.parse_args(argv)

    mesh = None
    if args.model_parallel > 0:
        pol = elastic.ElasticPolicy(model_parallel=args.model_parallel)
        dshape = pol.resolve_mesh(len(jax.devices()))
        n = dshape[0] * dshape[1]
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:n]).reshape(dshape), ("data", "model"))
        print(f"[elastic] resolved mesh data={dshape[0]} model={dshape[1]} "
              f"from {len(jax.devices())} devices", flush=True)

    cfg, model, opt, mesh, jitted, pipeline, state_sh = build(
        args.arch, args.smoke, args.sell, args.seq_len, args.global_batch,
        args.lr, args.steps, args.accum_steps, mesh=mesh,
        compress_grads=args.compress_grads, sell_method=args.sell_method,
        sell_transform=args.sell_transform)
    compress_dp = dict(mesh.shape)["data"] if args.compress_grads else 0

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    hb = elastic.Heartbeat().install()
    monitor = elastic.StragglerMonitor()
    obs = _train_metrics()
    exporter = (JsonlExporter(args.metrics_jsonl, REGISTRY,
                              every=args.log_every, clock=time.time)
                if args.metrics_jsonl else None)

    with mesh:
        start_step = 0
        if args.resume and ckpt.latest_step() is not None:
            latest = ckpt.latest_step()
            state = _restore(ckpt, latest, model, cfg, opt, compress_dp,
                             state_sh)
            start_step = int(latest)
            print(f"resumed from step {start_step} (elastic restore onto "
                  f"{dict(mesh.shape)})", flush=True)
        else:
            state = steps_mod.init_state(model, cfg, opt,
                                         jax.random.PRNGKey(0),
                                         compress_dp=compress_dp)
            state = jax.device_put(state, state_sh)

        if args.compress_grads:
            wire, raw = _grad_wire_bytes(state["params"])
            obs["wire"].set(wire)
            obs["raw"].set(raw)
            print(f"[compress] grad wire bytes {wire} vs fp32 {raw} "
                  f"({wire / max(raw, 1):.3f}x)", flush=True)

        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = pipeline.batch_at(step)
            state, metrics = jitted(state, batch)
            # sync before timing: dispatch is async, so the unblocked wall
            # time is just the enqueue cost (~ms) — the straggler monitor
            # would seed its EWMA from that and flag every real measurement
            jax.block_until_ready(metrics)
            dt = time.time() - t0
            obs["loss"].set(float(metrics["loss"]))
            obs["tps"].set(args.global_batch * args.seq_len / max(dt, 1e-9))
            obs["step_s"].observe(dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                print(f"step {step:5d} loss {loss:.4f} |g| {gn:.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)
                _emit_diag_norms(obs["diag"], state["params"])
                if exporter is not None:
                    exporter.export(step)
            # the first step's wall time is dominated by jit compilation —
            # seeding the EWMA with it would mask real stragglers for the
            # first dozens of steps (also after every resume/recompile)
            if step > start_step and monitor.observe(step, dt):
                print(f"[straggler] step {step} exceeded "
                      f"{monitor.factor}x EWMA", flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, state, extra={"arch": args.arch})
            if hb.should_stop:
                print("[preempt] SIGTERM received: draining + checkpointing")
                ckpt.wait()
                ckpt.save(step + 1, state, extra={"arch": args.arch})
                break
        else:
            # completed (no preempt break): the final save must not run on
            # the drain path — it would mislabel a mid-run state as
            # ``args.steps`` and a resumed job would think training is done.
            ckpt.wait()
            ckpt.save(args.steps, state, extra={"arch": args.arch})
    if exporter is not None:
        exporter.close()
        print(f"[obs] metrics jsonl -> {args.metrics_jsonl} "
              f"({exporter.exports} snapshots)", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
