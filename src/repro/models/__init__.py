"""Model zoo: one registry over the four architecture families.

``get_model(cfg)`` returns a uniform functional interface:

    model.init(rng, cfg)                     -> params
    model.apply(params, tokens, cfg, fe)     -> logits        (train/prefill)
    model.loss_fn(params, batch, cfg)        -> scalar loss
    model.init_cache(cfg, batch, max_len)    -> decode cache
    model.decode_step(params, cache, t, pos, cfg) -> (logits, cache)
    model.prefill(params, cache, tokens, cfg, lengths, fe)
                                             -> (logits (B,S,V), cache)
    model.init_cache_paged(cfg, batch, n_blocks, block_size)
                                             -> paged decode cache
    model.decode_step_paged(params, cache, t, pos, tables, cfg)
                                             -> (logits, cache)
    model.verify_step(params, cache, toks (B,T), pos, cfg)
                                             -> (logits (B,T,V), cache,
                                                 states | None)
    model.verify_step_paged(params, cache, toks, pos, tables, cfg)
                                             -> same, paged KV

The paged pair is None for families with no length-proportional KV to
page (mamba2's recurrent state is O(1) per slot by construction); the
verify pair is the speculative-decoding append-and-score path (KV leaves
set-written so rollback is a position rewind; ``states`` carries
per-position snapshots of the ``recurrent_keys`` cache leaves, which
cannot rewind and are re-committed at the accepted length instead).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.models import encdec, mamba2, transformer, zamba2
from repro.models.common import ModelConfig  # noqa: F401


@dataclasses.dataclass(frozen=True)
class Model:
    init: Callable
    apply: Callable
    loss_fn: Callable
    init_cache: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    prefill: Optional[Callable] = None
    init_cache_paged: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None
    verify_step: Optional[Callable] = None
    verify_step_paged: Optional[Callable] = None
    #: cache keys whose state is truly recurrent (snapshot-rollback)
    recurrent_keys: tuple = ()
    module: Any = None


_FAMILIES = {
    "decoder": transformer,
    "ssm": mamba2,
    "hybrid": zamba2,
    "encdec": encdec,
}


def get_model(cfg: ModelConfig) -> Model:
    mod = _FAMILIES[cfg.family]
    return Model(
        init=mod.init,
        apply=mod.apply,
        loss_fn=mod.loss_fn,
        init_cache=getattr(mod, "init_cache", None),
        decode_step=getattr(mod, "decode_step", None),
        prefill=getattr(mod, "prefill", None),
        init_cache_paged=getattr(mod, "init_cache_paged", None),
        decode_step_paged=getattr(mod, "decode_step_paged", None),
        verify_step=getattr(mod, "verify_step", None),
        verify_step_paged=getattr(mod, "verify_step_paged", None),
        recurrent_keys=tuple(getattr(mod, "RECURRENT_CACHE_KEYS", ())),
        module=mod,
    )
