"""Grouped-query attention with RoPE variants, sliding windows and KV cache.

One implementation serves: full attention (deepseek/llava), GQA with few KV
heads (chatglm3 kv=2), qk-norm (qwen3), partial-rotary "2d" RoPE (chatglm3),
per-layer local/global windows (gemma3 5:1), logit soft-capping, and the
cross-attention used by the encoder-decoder (seamless).

Train path computes full (Sq, Sk) score tiles with a dynamic causal+window
mask so heterogeneous layer patterns survive ``lax.scan``.  Decode path
appends one token to the cache and attends over the prefix.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import paged_attn as paged_attn_mod
from repro.models import linear
from repro.models.common import (
    ModelConfig,
    apply_rope,
    causal_window_mask,
    init_rms_norm,
    rms_norm,
)


def init_attention(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32,
                   cross: bool = False) -> dict:
    dh = cfg.head_dim_
    d = cfg.d_model
    rq, rk, rv, ro = jax.random.split(rng, 4)
    p = {
        "wq": linear.linear_init(rq, d, cfg.n_heads * dh, cfg, "attn_qkv", dtype),
        "wk": linear.linear_init(rk, d, cfg.n_kv_heads * dh, cfg, "attn_qkv", dtype),
        "wv": linear.linear_init(rv, d, cfg.n_kv_heads * dh, cfg, "attn_qkv", dtype),
        "wo": linear.linear_init(ro, cfg.n_heads * dh, d, cfg, "attn_out", dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(dh, dtype)
        p["k_norm"] = init_rms_norm(dh, dtype)
    return p


def _project_qkv(params: dict, xq: jax.Array, xkv: jax.Array,
                 cfg: ModelConfig):
    dh = cfg.head_dim_
    d = cfg.d_model
    q = linear.linear_apply(params["wq"], xq, d, cfg.n_heads * dh, cfg, "attn_qkv")
    k = linear.linear_apply(params["wk"], xkv, d, cfg.n_kv_heads * dh, cfg, "attn_qkv")
    v = linear.linear_apply(params["wv"], xkv, d, cfg.n_kv_heads * dh, cfg, "attn_qkv")
    q = q.reshape(*xq.shape[:-1], cfg.n_heads, dh)
    k = k.reshape(*xkv.shape[:-1], cfg.n_kv_heads, dh)
    v = v.reshape(*xkv.shape[:-1], cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"]["scale"], cfg.norm_eps)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array], cfg: ModelConfig) -> jax.Array:
    """q: (B, Sq, Hq, Dh), k/v: (B, Sk, Hkv, Dh) -> (B, Sq, Hq, Dh)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores * (dh ** -0.5)
    if cfg.attn_logit_softcap > 0:
        cap = cfg.attn_logit_softcap
        scores = cap * jnp.tanh(scores / cap)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, window: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """Flash-structured attention: online softmax over KV chunks.

    Never materializes the (Sq, Sk) score matrix — live memory is
    O(Sq * chunk) — which removes the dominant HBM-traffic term of vanilla
    attention at training/prefill sequence lengths (see EXPERIMENTS.md
    section Perf, hillclimb #1).  Same math as :func:`_sdpa` including the
    causal+window mask and logit soft-capping; numerics verified by
    tests/test_attention_impls.py.
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    group = hq // hkv
    chunk = min(cfg.attn_chunk, sk)
    n_chunks = sk // chunk if sk % chunk == 0 else -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(b, sq, hkv, group, dh).astype(jnp.float32)
    scale = dh ** -0.5
    q_pos = positions            # (B, Sq)
    kpos_full = jnp.arange(n_chunks * chunk, dtype=jnp.int32)

    def body(carry, idx):
        m, l, acc = carry        # m,l: (B,Hkv,G,Sq); acc: (B,Hkv,G,Sq,Dh)
        start = idx * chunk
        kc = jax.lax.dynamic_slice_in_dim(k, start, chunk, 1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, chunk, 1)
        kp = jax.lax.dynamic_slice_in_dim(kpos_full, start, chunk, 0)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       kc.astype(jnp.float32)) * scale
        if cfg.attn_logit_softcap > 0:
            cap = cfg.attn_logit_softcap
            s = cap * jnp.tanh(s / cap)
        valid = kp < sk  # (Ck,) — mask the padded tail chunk
        msk = causal_window_mask(q_pos, kp[None, :], window)  # (B, Sq, Ck)
        msk = jnp.logical_and(msk, valid[None, None, :])
        s = jnp.where(msk[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), jnp.arange(n_chunks, dtype=jnp.int32),
        unroll=cfg.scan_unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def attention_prefill(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    window: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Causal self-attention over a whole prompt, keeping K/V for the cache.

    x: (B, S, D) -> (out (B, S, D), k, v (B, S, Hkv, Dh)).  The returned
    k is post-RoPE — exactly the layout :func:`attention_decode` appends,
    so a prefill scatter followed by decode steps is state-identical to
    feeding the prompt token-by-token.
    """
    q, k, v = _project_qkv(params, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    if cfg.attn_impl == "chunked":
        out = _sdpa_chunked(q, k, v, positions, window, cfg)
    else:
        mask = causal_window_mask(positions, positions, window)
        out = _sdpa(q, k, v, mask, cfg)
    dh = cfg.head_dim_
    out = out.reshape(*x.shape[:-1], cfg.n_heads * dh)
    out = linear.linear_apply(params["wo"], out, cfg.n_heads * dh,
                              cfg.d_model, cfg, "attn_out")
    return out, k, v


def scatter_prefill_kv(
    k: jax.Array,                    # (B, S, Hkv, Dh) post-RoPE prompt keys
    v: jax.Array,
    lengths: jax.Array,              # (B,) valid prompt length per row
    max_len: int,
) -> Tuple[jax.Array, jax.Array]:
    """Lay prompt K/V into a fresh (B, max_len, Hkv, Dh) cache slab.

    Positions >= the row's length are ZERO — :func:`attention_decode`
    appends additively (cache + onehot * k), so any stale value at a
    future position would corrupt the first decode write there.  The slab
    overwrites the slot's previous occupant entirely (continuous batching
    reuses slots without a separate reset pass).
    """
    b, s = k.shape[:2]
    pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
    valid = (jnp.arange(max_len, dtype=jnp.int32)[None, :]
             < lengths[:, None])[:, :, None, None]
    return (jnp.where(valid, jnp.pad(k, pad), 0),
            jnp.where(valid, jnp.pad(v, pad), 0))


def attention(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    window: jax.Array,
    cfg: ModelConfig,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    kv_positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Self-attention (kv=None) or cross-attention (kv = encoder k/v source).

    x: (B, S, D); positions: (B, S); window: traced int32 scalar (0=global).
    """
    if kv is None:
        out, _, _ = attention_prefill(params, x, positions, window, cfg)
        return out
    # cross-attention: no RoPE, full visibility over encoder states
    q, k, v = _project_qkv(params, x, kv[0], cfg)
    out = _sdpa(q, k, v, None, cfg)
    dh = cfg.head_dim_
    out = out.reshape(*x.shape[:-1], cfg.n_heads * dh)
    return linear.linear_apply(params["wo"], out, cfg.n_heads * dh,
                               cfg.d_model, cfg, "attn_out")


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache).
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int, dtype) -> dict:
    dh = cfg.head_dim_
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def init_kv_cache_paged(cfg: ModelConfig, n_blocks: int, block_size: int,
                        n_layers: int, dtype) -> dict:
    """Global page pool replacing the per-slot ``max_len`` slabs.

    One extra physical page (index ``n_blocks``) is the write sink: decode
    writes from parked/stalled batch rows are routed there instead of into
    a mapped page, and nothing ever reads it back.  Block ids and per-slot
    tables are owned by :class:`repro.serving.blocks.BlockAllocator`.
    """
    dh = cfg.head_dim_
    shape = (n_layers, n_blocks + 1, block_size, cfg.n_kv_heads, dh)
    return {
        "k_pages": jnp.zeros(shape, dtype),
        "v_pages": jnp.zeros(shape, dtype),
    }


def scatter_prefill_pages(
    pages: jax.Array,                # (L, NB+1, bs, ...) page pool
    slab: jax.Array,                 # (L, 1, S, ...) dense prefill slab
    phys_blocks: jax.Array,          # (S // bs,) physical page per block
) -> jax.Array:
    """Paged prefill scatter: lay a batch-1 dense KV slab into the pool.

    ``phys_blocks`` is the slot's block-table row with unmapped entries
    already routed to the trash page, so blocks beyond the prompt write
    harmlessly into the sink.  Whole pages are overwritten (zeros beyond
    the prompt length included), so a remapped page needs no reset pass.
    """
    n_layers = slab.shape[0]
    s = slab.shape[2]
    bs = pages.shape[2]
    vals = slab[:, 0].reshape(n_layers, s // bs, bs, *slab.shape[3:])
    return pages.at[:, phys_blocks].set(vals.astype(pages.dtype))


def _attention_paged(
    params: dict,
    x: jax.Array,                   # (B, T, D); T=1 decode, T=k+1 verify
    k_pages: jax.Array,             # (NB+1, bs, Hkv, Dh) — this layer's pool
    v_pages: jax.Array,
    block_tables: jax.Array,        # (B, MB) int32, -1 = unmapped
    position: jax.Array,            # (B,) first write index per row
    window: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared paged decode/verify body — decode is the T=1 case.

    The T new tokens' K/V is set-scattered into their tail pages
    (``block_tables[b, pos // bs]``, offset ``pos % bs``); tokens whose
    page is unmapped or whose position is at/beyond the virtual row
    length (parked/stalled slots) write to the trash page instead.

    Attention dispatches through ``ops.paged_attn_route`` (the single
    call site for both grid shapes): in budget on a real device — or
    under ``paged_attn.FORCE_FUSED`` — the fused Pallas kernel walks the
    block table and streams only mapped, in-frontier pages (O(len)
    bytes/slot); otherwise this gather fallback materializes the
    ``(B, MB*bs, ...)`` virtual view page-wise through the table
    (unmapped entries read page 0, whose stale contents sit beyond the
    causal frontier and are masked) and runs plain SDPA.  Greedy streams
    are identical either way (pinned by tests/test_paged_attention.py).
    """
    b, t, _ = x.shape
    n_pages, bs = k_pages.shape[0], k_pages.shape[1]
    mb = block_tables.shape[1]
    virtual = mb * bs
    dh = cfg.head_dim_
    q, k, v = _project_qkv(params, x, x, cfg)
    pos = position[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B,T)
    q = apply_rope(q, pos, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_fraction, cfg.rope_theta)

    blk = ops.paged_attn_route(cfg.n_kv_heads, dh,
                               cfg.n_heads // cfg.n_kv_heads, t, bs,
                               k_pages.dtype)
    if blk is not None:
        pc, bh = blk
        out, k_pages, v_pages = paged_attn_mod.paged_attention(
            q, k, v, k_pages, v_pages, block_tables, position, window,
            softcap=cfg.attn_logit_softcap, page_chunk=pc, head_block=bh,
            interpret=ops._INTERPRET)
    else:
        blk_idx = jnp.minimum(pos // bs, mb - 1)                       # (B,T)
        phys = jnp.take_along_axis(block_tables, blk_idx, axis=1)      # (B,T)
        writable = jnp.logical_and(phys >= 0, pos < virtual)
        phys = jnp.where(writable, phys, n_pages - 1)                  # sink
        off = pos % bs
        k_pages = k_pages.at[phys, off].set(k.astype(k_pages.dtype))
        v_pages = v_pages.at[phys, off].set(v.astype(v_pages.dtype))

        tbl = jnp.where(block_tables >= 0, block_tables, 0)            # (B,MB)
        ck = k_pages[tbl].reshape(b, virtual, *k_pages.shape[2:])
        cv = v_pages[tbl].reshape(b, virtual, *v_pages.shape[2:])
        k_pos = jnp.arange(virtual, dtype=jnp.int32)[None, :]
        mask = causal_window_mask(pos, k_pos, window)                  # (B,T,V)
        out = _sdpa(q, ck, cv, mask, cfg)
    out = out.reshape(b, t, cfg.n_heads * dh)
    out = linear.linear_apply(params["wo"], out, cfg.n_heads * dh,
                              cfg.d_model, cfg, "attn_out")
    return out, k_pages, v_pages


def attention_decode_paged(
    params: dict,
    x: jax.Array,                   # (B, 1, D)
    k_pages: jax.Array,             # (NB+1, bs, Hkv, Dh) — this layer's pool
    v_pages: jax.Array,
    block_tables: jax.Array,        # (B, MB) int32, -1 = unmapped
    position: jax.Array,            # (B,) current index
    window: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Paged twin of :func:`attention_decode`: the T=1 grid shape of
    :func:`_attention_paged`."""
    return _attention_paged(params, x, k_pages, v_pages, block_tables,
                            position, window, cfg)


def attention_verify(
    params: dict,
    x: jax.Array,                   # (B, T, D) — pending token + k drafts
    cache_k: jax.Array,             # (B, Smax, Hkv, Dh) — this layer's slice
    cache_v: jax.Array,
    position: jax.Array,            # (B,) first write index per row
    window: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Append-and-score T tokens against the dense cache in one pass.

    The speculative-decode verify primitive: row ``b``'s tokens occupy
    positions ``position[b] .. position[b] + T - 1``.  K/V is written with
    ``set`` (NOT the additive decode scatter), so a later rollback is just
    a position rewind — stale values beyond the new frontier sit past the
    causal mask and are overwritten exactly by the next set-write.  Rows
    whose position is parked (at/beyond ``Smax``) write nothing (the
    scatter drops out-of-bounds indices).  Per position the math matches
    :func:`attention_decode` reduction-for-reduction, so greedy argmax
    agreement with token-at-a-time decode is exact.
    """
    b, t, _ = x.shape
    smax = cache_k.shape[1]
    q, k, v = _project_qkv(params, x, x, cfg)
    pos = position[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B,T)
    q = apply_rope(q, pos, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_fraction, cfg.rope_theta)
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]                     # (B,1)
    cache_k = cache_k.at[bidx, pos].set(k.astype(cache_k.dtype), mode="drop")
    cache_v = cache_v.at[bidx, pos].set(v.astype(cache_v.dtype), mode="drop")
    k_pos = jnp.arange(smax, dtype=jnp.int32)[None, :]                 # (1,Smax)
    mask = causal_window_mask(pos, k_pos, window)                      # (B,T,Smax)
    out = _sdpa(q, cache_k, cache_v, mask, cfg)
    dh = cfg.head_dim_
    out = out.reshape(b, t, cfg.n_heads * dh)
    out = linear.linear_apply(params["wo"], out, cfg.n_heads * dh,
                              cfg.d_model, cfg, "attn_out")
    return out, cache_k, cache_v


def attention_verify_paged(
    params: dict,
    x: jax.Array,                   # (B, T, D)
    k_pages: jax.Array,             # (NB+1, bs, Hkv, Dh) — this layer's pool
    v_pages: jax.Array,
    block_tables: jax.Array,        # (B, MB) int32, -1 = unmapped
    position: jax.Array,            # (B,) first write index per row
    window: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Paged twin of :func:`attention_verify`: the T=k+1 grid shape of
    :func:`_attention_paged`.

    The engine pre-maps pages for the whole verify window
    (``ensure_range``) or parks the row; unmapped or parked positions
    route to the trash page.  Rollback is a position rewind plus
    returning over-mapped tail pages — page contents are never cleaned,
    exactly like the single-token decode path.
    """
    return _attention_paged(params, x, k_pages, v_pages, block_tables,
                            position, window, cfg)


def attention_decode(
    params: dict,
    x: jax.Array,                   # (B, 1, D)
    cache_k: jax.Array,             # (B, Smax, Hkv, Dh) — this layer's slice
    cache_v: jax.Array,
    position: jax.Array,            # (B,) current index
    window: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out (B,1,D), new_cache_k, new_cache_v)."""
    b, _, _ = x.shape
    smax = cache_k.shape[1]
    q, k, v = _project_qkv(params, x, x, cfg)
    pos2 = position[:, None]  # (B,1)
    q = apply_rope(q, pos2, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_fraction, cfg.rope_theta)
    # scatter the new k/v at `position`
    onehot = jax.nn.one_hot(position, smax, dtype=k.dtype)  # (B, Smax)
    cache_k = cache_k + onehot[:, :, None, None] * k
    cache_v = cache_v + onehot[:, :, None, None] * v
    k_pos = jnp.arange(smax, dtype=jnp.int32)[None, :]  # (1, Smax)
    # causal also excludes unwritten cache slots (they sit beyond `position`)
    mask = causal_window_mask(pos2, k_pos, window)      # (B, 1, Smax)
    out = _sdpa(q, cache_k, cache_v, mask, cfg)
    dh = cfg.head_dim_
    out = out.reshape(b, 1, cfg.n_heads * dh)
    out = linear.linear_apply(params["wo"], out, cfg.n_heads * dh,
                              cfg.d_model, cfg, "attn_out")
    return out, cache_k, cache_v
