"""Shared model configuration and primitive layers for the model zoo.

One ``ModelConfig`` dataclass covers all ten assigned architectures; the
family field selects the top-level assembly (decoder / encdec / ssm /
hybrid).  The paper's technique is integrated through ``sell_kind`` /
``sell_targets``: any projection listed in ``sell_targets`` is built as a
structured efficient linear layer (default ACDC cascade) instead of a dense
matrix — see ``repro/models/linear.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder"          # decoder | encdec | ssm | hybrid
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1000
    head_dim: Optional[int] = None   # default d_model // n_heads
    max_seq_len: int = 8192

    # --- attention flavour ---
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm3 "2d RoPE": rotary on half dims
    qk_norm: bool = False            # qwen3
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every k-th layer is global
    attn_logit_softcap: float = 0.0

    # --- mlp flavour ---
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    d_inner: int = 0                 # default 2*d_model
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (zamba2) ---
    attn_every: int = 0              # shared attn block every k ssm layers

    # --- enc-dec (seamless) ---
    n_encoder_layers: int = 0

    # --- modality frontends (stubs per assignment) ---
    frontend: Optional[str] = None   # "vision" | "audio"
    n_frontend_tokens: int = 0       # patches / audio frames per example

    # --- SELL integration (the paper's technique) ---
    sell_kind: str = "dense"         # dense | acdc | fastfood | circulant | low_rank
    sell_k: int = 2                  # cascade depth per replaced projection
    # projection roles the SELL replaces (prefix match): attention output,
    # gated-MLP, mamba in/out, zamba shared-block input.  "attn_qkv" and
    # "expert" are deliberately opt-in.
    sell_targets: Tuple[str, ...] = ("attn_out", "mlp", "ssm", "shared_in")
    sell_relu: bool = False
    sell_permute: bool = True
    sell_init_std: float = 0.061     # paper section 6.2 identity+noise scale
    sell_rank: int = 64              # for the low_rank baseline
    sell_method: str = "auto"        # transform backend: auto|fft|matmul|pallas
    # transform family for sell_kind='acdc' cascades — any name registered
    # in core/families.py ("acdc" = DCT-II, "circulant" = real-DFT basis,
    # "hadamard" = Walsh-Hadamard; the latter pads n_op to a power of two).
    sell_transform: str = "acdc"
    # pin SELL activations to batch-only sharding (feature axis local) so
    # the DCT/FFT never crosses a sharded dim — see linear.py and
    # EXPERIMENTS.md §Perf hillclimb #3 (False reproduces the naive +119x
    # collective blowup).  sell_batch_axes names the mesh axes the batch
    # dim may shard over (set by the launcher/dry-run per mesh).
    sell_local_features: bool = True
    sell_batch_axes: Tuple[str, ...] = ()

    # --- performance knobs (see EXPERIMENTS.md section Perf) ---
    # Defaults are the OPTIMIZED implementations (hillclimb-confirmed,
    # equivalence-tested in tests/test_perf_impls.py); the paper-faithful
    # baselines stay selectable ("vanilla"/"gather"/"einsum").
    # "vanilla": materialize (Sq, Sk) scores  |  "chunked": online-softmax
    # over KV chunks, O(S*chunk) live memory (flash-attention structure).
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    # "gather": take_along_axis over the vocab axis (all-gathers sharded
    # logits)  |  "onehot": lse - sum(logits*onehot) (psum-friendly).
    ce_impl: str = "onehot"
    # "einsum": one-hot dispatch/combine einsums, O(T*E*C*d) FLOPs
    # "scatter": scatter/gather dispatch, O(T*k*d) FLOPs.
    moe_impl: str = "scatter"

    # --- numerics / misc ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True
    # Unroll the layer scans (roofline analysis only): XLA's cost_analysis
    # counts a while-loop body ONCE, so per-layer costs must be measured on
    # unrolled (small-L) compiles and extrapolated.  Never set on full
    # configs — compile time is O(L).
    scan_unroll: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def param_dtype(self):
        return jnp.float32  # master weights; compute casts to self.dtype

    @property
    def compute_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window (0 = global), e.g. gemma3's 5:1."""
        w = np.full((self.n_layers,), self.sliding_window, dtype=np.int32)
        if self.global_every > 0:
            w[self.global_every - 1 :: self.global_every] = 0
        return w


# ---------------------------------------------------------------------------
# Primitive layers (functional, params = dict pytrees).
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}  # stored as (scale - 1)


def embed_init(rng: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(rng, (vocab, d), dtype) * (d ** -0.5)}


def embed_lookup(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    # logits in fp32 for a stable softmax-xent
    return jnp.matmul(x.astype(jnp.float32), params["table"].astype(jnp.float32).T)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float) -> jax.Array:
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv  # (rot_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, fraction: float,
               theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    rot_dim = int(dh * fraction) // 2 * 2
    if rot_dim == 0:
        return x
    inv = rope_frequencies(dh, fraction, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rotated = jnp.stack([out1, out2], axis=-1).reshape(*x1.shape[:-1], rot_dim)
    return jnp.concatenate(
        [rotated.astype(x.dtype), x[..., rot_dim:]], axis=-1
    )


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  cfg: "ModelConfig") -> jax.Array:
    """Masked next-token CE.  Two implementations:

    * "gather" — take_along_axis over the vocab axis.  Under vocab-sharded
      (TP) logits, XLA SPMD resolves the gather by ALL-GATHERING the full
      (tokens, V) logits — the dominant collective in the baseline roofline
      (EXPERIMENTS.md section Perf, hillclimb #2).
    * "onehot" — lse(logits) - sum(logits * onehot(labels)): both terms are
      vocab-axis reductions, so the sharded dimension reduces locally and
      only (tokens,) scalars cross the mesh (psum).
    """
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    if cfg.ce_impl == "onehot":
        lf = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
        true_logit = jnp.sum(lf * onehot, axis=-1)
        nll = lse - true_logit
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def causal_window_mask(q_pos: jax.Array, k_pos: jax.Array,
                       window: jax.Array) -> jax.Array:
    """Boolean mask (..., Sq, Sk): causal AND within sliding window.

    ``window`` is a traced int32 scalar; 0 means no window (global).  This
    keeps local and global layers on ONE code path so layer heterogeneity
    (gemma3's 5:1) survives ``lax.scan`` over stacked layer params.
    """
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    causal = dk <= dq
    dist = dq - dk
    in_window = jnp.where(window > 0, dist < window, True)
    return jnp.logical_and(causal, in_window)
