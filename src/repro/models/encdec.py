"""Encoder-decoder transformer (SeamlessM4T-v2 text/audio backbone).

Per the assignment, the modality frontend is a STUB: ``input_specs`` feeds
precomputed audio frame embeddings (B, frames, D) into the encoder; the
decoder is a causal transformer with cross-attention over encoder states.

Decode path caches both the decoder self-attention KV and the (static)
cross-attention KV computed once from the encoder output at prefill.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import (
    ModelConfig,
    cross_entropy,
    embed_init,
    embed_lookup,
    init_rms_norm,
    rms_norm,
    unembed,
)


def init_encoder_layer(rng, cfg, dtype):
    ra, rm = jax.random.split(rng)
    return {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(ra, cfg, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
        "mlp": mlp_mod.init_mlp(rm, cfg, None, dtype),
    }


def init_decoder_layer(rng, cfg, dtype):
    ra, rx, rm = jax.random.split(rng, 3)
    return {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(ra, cfg, dtype),
        "norm_x": init_rms_norm(cfg.d_model, dtype),
        "cross": attn_mod.init_attention(rx, cfg, dtype, cross=True),
        "norm2": init_rms_norm(cfg.d_model, dtype),
        "mlp": mlp_mod.init_mlp(rm, cfg, None, dtype),
    }


def init(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = cfg.param_dtype
    re, renc, rdec = jax.random.split(rng, 3)
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda r: init_encoder_layer(r, cfg, dtype))(
            jax.random.split(renc, n_enc)),
        "decoder": jax.vmap(lambda r: init_decoder_layer(r, cfg, dtype))(
            jax.random.split(rdec, cfg.n_layers)),
        "enc_norm": init_rms_norm(cfg.d_model, dtype),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }


def _enc_layer(layer, x, positions, cfg):
    # bidirectional: no causal mask -> emulate with window=0 and full mask
    h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
    # bidirectional self-attention: use cross-attention path (mask=None)
    x = x + attn_mod.attention(layer["attn"], h, positions, jnp.zeros((), jnp.int32),
                               cfg, kv=(h,), kv_positions=positions)
    h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
    return x + mlp_mod.mlp(layer["mlp"], h, cfg), None


def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, F, D) stub audio embeddings -> encoder states."""
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))

    fn = _enc_layer
    if cfg.remat:
        fn = jax.checkpoint(_enc_layer,
                            policy=jax.checkpoint_policies.nothing_saveable,
                            static_argnums=(3,))

    def body(carry, layer):
        y, _ = fn(layer, carry, positions, cfg)
        return y, None

    x, _ = jax.lax.scan(body, frames.astype(cfg.compute_dtype),
                        params["encoder"], unroll=cfg.scan_unroll)
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _dec_layer(layer, x, enc, positions, cfg):
    window = jnp.zeros((), jnp.int32)
    h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
    x = x + attn_mod.attention(layer["attn"], h, positions, window, cfg)
    h = rms_norm(x, layer["norm_x"]["scale"], cfg.norm_eps)
    x = x + attn_mod.attention(layer["cross"], h, positions, window, cfg,
                               kv=(enc,))
    h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
    return x + mlp_mod.mlp(layer["mlp"], h, cfg)


def apply(params: dict, tokens: jax.Array, cfg: ModelConfig,
          frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens: (B, S) decoder input; frontend_embeds: (B, F, D) audio stub."""
    assert frontend_embeds is not None, "enc-dec needs frontend embeddings"
    enc = encode(params, frontend_embeds, cfg)
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    fn = _dec_layer
    if cfg.remat:
        fn = jax.checkpoint(_dec_layer,
                            policy=jax.checkpoint_policies.nothing_saveable,
                            static_argnums=(4,))

    def body(carry, layer):
        return fn(layer, carry, enc, positions, cfg), None

    x, _ = jax.lax.scan(body, x, params["decoder"],
                        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits = apply(params, batch["tokens"], cfg, batch["frontend_embeds"])
    return cross_entropy(logits, batch["labels"], cfg)


# ---------------------------------------------------------------------------
# Decode.
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    kv = attn_mod.init_kv_cache(cfg, batch, max_len, cfg.n_layers,
                                cfg.compute_dtype)
    frames = cfg.n_frontend_tokens or 128
    dh = cfg.head_dim_
    return {
        "k": kv["k"],
        "v": kv["v"],
        # cross-attention KV, filled at prefill from encoder states
        "xk": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads, dh),
                        cfg.compute_dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads, dh),
                        cfg.compute_dtype),
    }


def init_cache_paged(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int) -> dict:
    """Paged decoder self-attention KV.  The cross KV stays dense: it is
    frames-sized per slot (static, written once at prefill), so there is
    no ragged-length waste to reclaim by paging it."""
    kv = attn_mod.init_kv_cache_paged(cfg, n_blocks, block_size,
                                      cfg.n_layers, cfg.compute_dtype)
    frames = cfg.n_frontend_tokens or 128
    dh = cfg.head_dim_
    return {
        "k_pages": kv["k_pages"],
        "v_pages": kv["v_pages"],
        "xk": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads, dh),
                        cfg.compute_dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, frames, cfg.n_kv_heads, dh),
                        cfg.compute_dtype),
    }


def prefill_cross(params: dict, cache: dict, frames: jax.Array,
                  cfg: ModelConfig) -> dict:
    """Run the encoder once and precompute per-layer cross KV."""
    enc = encode(params, frames, cfg)
    dh = cfg.head_dim_

    def one_layer(layer):
        k = attn_mod.linear.linear_apply(
            layer["cross"]["wk"], enc, cfg.d_model,
            cfg.n_kv_heads * dh, cfg, "attn_qkv")
        v = attn_mod.linear.linear_apply(
            layer["cross"]["wv"], enc, cfg.d_model,
            cfg.n_kv_heads * dh, cfg, "attn_qkv")
        k = k.reshape(*enc.shape[:-1], cfg.n_kv_heads, dh)
        v = v.reshape(*enc.shape[:-1], cfg.n_kv_heads, dh)
        return k, v

    xk, xv = jax.vmap(one_layer)(params["decoder"])
    return {**cache, "xk": xk.astype(cfg.compute_dtype),
            "xv": xv.astype(cfg.compute_dtype)}


def prefill(params: dict, cache: dict, tokens: jax.Array, cfg: ModelConfig,
            lengths=None, frontend_embeds=None):
    """Batched decoder prompt pass -> (logits (B,S,V), cache).

    If ``frontend_embeds`` is given the encoder runs first
    (:func:`prefill_cross`); otherwise the cache's existing cross K/V is
    used — the same precomputed layout :func:`decode_step` reads, so
    prefill-then-decode agrees with token-at-a-time decode exactly.
    """
    if frontend_embeds is not None:
        cache = prefill_cross(params, cache, frontend_embeds, cfg)
    b, s = tokens.shape
    smax = cache["k"].shape[2]
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    window = jnp.zeros((), jnp.int32)
    dh = cfg.head_dim_

    def body(carry, xs):
        x = carry
        layer, xk, xv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, k, v = attn_mod.attention_prefill(layer["attn"], h, positions,
                                               window, cfg)
        x = x + out
        # cross-attention against the precomputed encoder KV
        h = rms_norm(x, layer["norm_x"]["scale"], cfg.norm_eps)
        x = x + _cross_attend(layer, h, xk, xv, cfg)
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        ck, cv = attn_mod.scatter_prefill_kv(k, v, lengths, smax)
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["decoder"], cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {**cache, "k": new_k.astype(cache["k"].dtype),
                    "v": new_v.astype(cache["v"].dtype)}


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                position: jax.Array, cfg: ModelConfig):
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens[:, None], dtype)
    window = jnp.zeros((), jnp.int32)

    def body(carry, xs):
        x = carry
        layer, ck, cv, xk, xv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, ck, cv = attn_mod.attention_decode(
            layer["attn"], h, ck, cv, position, window, cfg)
        x = x + out
        # cross-attention against the precomputed encoder KV
        h = rms_norm(x, layer["norm_x"]["scale"], cfg.norm_eps)
        x = x + _cross_attend(layer, h, xk, xv, cfg)
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, {**cache, "k": nk, "v": nv}


def _cross_attend(layer: dict, h: jax.Array, xk: jax.Array, xv: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """Cross-attention of (B, T, D) queries over the precomputed encoder
    KV — shared by the decode, verify and prefill bodies (T = 1, k+1, S)."""
    dh = cfg.head_dim_
    q = attn_mod.linear.linear_apply(
        layer["cross"]["wq"], h, cfg.d_model, cfg.n_heads * dh,
        cfg, "attn_qkv").reshape(*h.shape[:-1], cfg.n_heads, dh)
    out = attn_mod._sdpa(q, xk, xv, None, cfg)
    out = out.reshape(*h.shape[:-1], cfg.n_heads * dh)
    return attn_mod.linear.linear_apply(
        layer["cross"]["wo"], out, cfg.n_heads * dh, cfg.d_model,
        cfg, "attn_out")


def verify_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T) pending token + k draft tokens
    position: jax.Array,      # (B,) first write position per row
    cfg: ModelConfig,
):
    """Speculative append-and-score (see transformer.verify_step): decoder
    self-attention KV set-written at ``position + i``, cross KV read-only."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    window = jnp.zeros((), jnp.int32)

    def body(carry, xs):
        x = carry
        layer, ck, cv, xk, xv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, ck, cv = attn_mod.attention_verify(
            layer["attn"], h, ck, cv, position, window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm_x"]["scale"], cfg.norm_eps)
        x = x + _cross_attend(layer, h, xk, xv, cfg)
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {**cache, "k": nk, "v": nv}, None


def verify_step_paged(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T)
    position: jax.Array,      # (B,)
    block_tables: jax.Array,  # (B, MB)
    cfg: ModelConfig,
):
    """Paged twin of :func:`verify_step`; cross KV stays dense."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    window = jnp.zeros((), jnp.int32)

    def body(carry, xs):
        x = carry
        layer, kp, vp, xk, xv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, kp, vp = attn_mod.attention_verify_paged(
            layer["attn"], h, kp, vp, block_tables, position, window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm_x"]["scale"], cfg.norm_eps)
        x = x + _cross_attend(layer, h, xk, xv, cfg)
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (kp, vp)

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["k_pages"], cache["v_pages"],
         cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {**cache, "k_pages": nk, "v_pages": nv}, None


def decode_step_paged(params: dict, cache: dict, tokens: jax.Array,
                      position: jax.Array, block_tables: jax.Array,
                      cfg: ModelConfig):
    """Mirror of :func:`decode_step` with self-attention KV paged; the
    precomputed cross KV rides along dense and untouched."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens[:, None], dtype)
    window = jnp.zeros((), jnp.int32)

    def body(carry, xs):
        x = carry
        layer, kp, vp, xk, xv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, kp, vp = attn_mod.attention_decode_paged(
            layer["attn"], h, kp, vp, block_tables, position, window, cfg)
        x = x + out
        # cross-attention against the precomputed encoder KV
        h = rms_norm(x, layer["norm_x"]["scale"], cfg.norm_eps)
        x = x + _cross_attend(layer, h, xk, xv, cfg)
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (kp, vp)

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["k_pages"], cache["v_pages"],
         cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, {**cache, "k_pages": nk, "v_pages": nv}
