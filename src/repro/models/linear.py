"""Projection factory: dense or SELL (the paper's technique) per config.

Every projection in the model zoo is created through :func:`linear_init` /
:func:`linear_apply` with a ``role`` tag (``attn_qkv``, ``attn_out``,
``mlp_in``, ``mlp_out``, ``expert`` ...).  When the role appears in
``cfg.sell_targets`` and ``cfg.sell_kind != 'dense'``, the projection is a
structured efficient linear layer — by default an order-K ACDC cascade with
TPU lane alignment — giving O(N) parameters instead of O(N^2).

This is the integration point that makes the paper's contribution a
first-class feature of the framework rather than a bolt-on.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sell as sell_mod
from repro.models.common import ModelConfig


def _sell_cfg(cfg: ModelConfig, n_in: int, n_out: int) -> sell_mod.SellConfig:
    return sell_mod.SellConfig(
        kind=cfg.sell_kind,
        n_in=n_in,
        n_out=n_out,
        k=cfg.sell_k,
        relu=cfg.sell_relu,
        permute=cfg.sell_permute,
        bias=False,  # LM convention: norms carry the biases
        init_std=cfg.sell_init_std,
        rank=cfg.sell_rank,
        method=cfg.sell_method,  # type: ignore[arg-type]
        transform=cfg.sell_transform,
        lane_multiple=128,
    )


def uses_sell(cfg: ModelConfig, role: str) -> bool:
    return cfg.sell_kind != "dense" and any(
        role.startswith(t) or t == role for t in cfg.sell_targets
    )


def linear_init(
    rng: jax.Array,
    n_in: int,
    n_out: int,
    cfg: ModelConfig,
    role: str,
    dtype=jnp.float32,
) -> dict:
    if uses_sell(cfg, role):
        scfg = _sell_cfg(cfg, n_in, n_out)
        return {"sell": sell_mod.init_sell_params(rng, scfg, dtype)}
    scale = 1.0 / np.sqrt(n_in)
    return {"w": scale * jax.random.normal(rng, (n_in, n_out), dtype)}


def _batch_local_constraint(x: jax.Array, batch_axes=()) -> jax.Array:
    """Constrain a SELL input/output to batch-only sharding.

    The DCT/FFT inside a SELL mixes the ENTIRE feature axis, so if the
    activation arrives feature-sharded (tensor-parallel layout), SPMD must
    all-gather it for every transform — measured at +119x collective bytes
    on qwen3.train_4k (EXPERIMENTS.md section Perf, hillclimb #3, refuted
    step).  Pinning SELL activations to (batch-sharded, feature-local)
    keeps the O(N log N) transform collective-free; the O(N) diagonals are
    replicated anyway.
    """
    try:
        if not batch_axes:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is None or not mesh.axis_names:
                return x
            batch_axes = tuple(a for a in ("pod", "data")
                               if a in mesh.axis_names)
        if not batch_axes:
            return x
        spec = [None] * x.ndim
        spec[0] = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except Exception:  # outside a mesh context (tests, examples)
        return x


def linear_apply(
    params: dict,
    x: jax.Array,
    n_in: int,
    n_out: int,
    cfg: ModelConfig,
    role: str,
) -> jax.Array:
    if "sell" in params:
        scfg = _sell_cfg(cfg, n_in, n_out)
        if cfg.sell_local_features:
            x = _batch_local_constraint(x, cfg.sell_batch_axes)
        y = sell_mod.structured_linear(params["sell"], x, scfg)
        if cfg.sell_local_features:
            y = _batch_local_constraint(y, cfg.sell_batch_axes)
        return y
    return jnp.matmul(x, params["w"].astype(x.dtype))


def linear_param_count(cfg: ModelConfig, role: str, n_in: int, n_out: int) -> int:
    if uses_sell(cfg, role):
        return _sell_cfg(cfg, n_in, n_out).param_count()
    return n_in * n_out
