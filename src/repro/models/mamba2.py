"""Mamba2 (state-space duality / SSD) blocks — arXiv:2405.21060.

Implements the chunked SSD algorithm for training (quadratic intra-chunk
"attention" + linear inter-chunk state recurrence, both MXU-shaped) and the
O(1)-per-token recurrent decode path with conv + SSM state caches — this is
what makes the ``long_500k`` shape runnable where full attention is not.

The block's in/out projections are built through the SELL factory, so the
paper's ACDC layer applies to the parameter mass (the projections) while the
SSD scan itself — already a structured, linear-time operator — is untouched
(see DESIGN.md section "Arch-applicability").
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import linear
from repro.models.common import (
    ModelConfig,
    cross_entropy,
    embed_init,
    embed_lookup,
    init_rms_norm,
    rms_norm,
    unembed,
)


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner_
    n_heads = d_in // cfg.ssm_head_dim
    n_state = cfg.ssm_state
    conv_dim = d_in + 2 * n_state  # x + B + C share the conv (ngroups=1)
    return d_in, n_heads, n_state, conv_dim


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------

def init_mamba_block(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    d_in, n_heads, n_state, conv_dim = _dims(cfg)
    r_in, r_conv, r_dt, r_a, r_out = jax.random.split(rng, 5)
    proj_out_dim = 2 * d_in + 2 * n_state + n_heads  # z, xBC, dt
    p = {
        "in_proj": linear.linear_init(r_in, d, proj_out_dim, cfg, "ssm_in", dtype),
        "conv_w": 0.1 * jax.random.normal(r_conv, (cfg.conv_width, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(r_dt, (n_heads,), dtype,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(jax.random.uniform(r_a, (n_heads,), dtype, 1.0, 16.0)),
        "d_skip": jnp.ones((n_heads,), dtype),
        "norm": init_rms_norm(d_in, dtype),
        "out_proj": linear.linear_init(r_out, d_in, d, cfg, "ssm_out", dtype),
    }
    return p


# ---------------------------------------------------------------------------
# Chunked SSD (training).
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) with out[i, j] = sum_{k=j+1..i} x[k], -inf above."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,       # (B, S, H, P) — already multiplied by dt
    a_log: jax.Array,   # (B, S, H)   — dt * A (negative)
    bmat: jax.Array,    # (B, S, N)
    cmat: jax.Array,    # (B, S, N)
    chunk: int,
    unroll: bool = False,
) -> jax.Array:
    """Minimal chunked SSD (Mamba2 paper listing, ngroups=1)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    out_dtype = x.dtype
    # state recurrences are numerically delicate: run the whole SSD in fp32
    # (matches the reference implementation; intra-chunk matmuls still hit
    # the MXU via bf16 inputs upcast at the unit).
    x = x.astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    xc = x.reshape(b, c, chunk, h, p)
    ac = a_log.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (B,H,C,L)
    bc = bmat.reshape(b, c, chunk, n)
    cc = cmat.reshape(b, c, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                            # (B,H,C,L)

    # 1. intra-chunk (diagonal blocks): "attention" with decay kernel
    l_mat = jnp.exp(_segsum(ac))                               # (B,H,C,L,L)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, l_mat, xc)

    # 2. chunk summary states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)            # (B,H,C,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3. inter-chunk recurrence (scan over chunk axis)
    chunk_decay = jnp.exp(a_cum[..., -1])                      # (B,H,C)

    def scan_fn(prev, inp):
        st, dec = inp                                          # (B,H,P,N), (B,H)
        new = prev * dec[..., None, None] + st
        return new, prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4),                      # (C,B,H,P,N)
         chunk_decay.transpose(2, 0, 1)),                      # (C,B,H)
        unroll=unroll)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (B,C,H,P,N)

    # 4. off-diagonal contribution from carried state
    state_decay = jnp.exp(a_cum)                               # (B,H,C,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(out_dtype)


def mamba_block(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    d_in, n_heads, n_state, conv_dim = _dims(cfg)
    proj_out = 2 * d_in + 2 * n_state + n_heads
    zxbcdt = linear.linear_apply(params["in_proj"], x, d, proj_out, cfg, "ssm_in")
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)

    # causal depthwise conv over (x, B, C)
    w = params["conv_w"].astype(x.dtype)  # (W, conv_dim)
    pad = cfg.conv_width - 1
    xbc_pad = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + s, :] * w[i]
        for i in range(cfg.conv_width)
    ) + params["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(conv)

    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)
    xs = xs.reshape(b, s, n_heads, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # (H,)

    y = ssd_chunked(
        (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype),
        (dt * a).astype(jnp.float32),
        bmat.astype(x.dtype),
        cmat.astype(x.dtype),
        cfg.ssm_chunk,
        unroll=cfg.scan_unroll,
    )
    y = y + xs * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"]["scale"], cfg.norm_eps)
    return linear.linear_apply(params["out_proj"], y, d_in, d, cfg, "ssm_out")


# ---------------------------------------------------------------------------
# Prefill: one batched pass over the prompt, recovering the decode caches.
# ---------------------------------------------------------------------------

def mamba_block_prefill(
    params: dict,
    x: jax.Array,            # (B, S, D) right-padded prompt hidden states
    cfg: ModelConfig,
    mask: jax.Array,         # (B, S) True at real (non-pad) positions
    lengths: jax.Array,      # (B,)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Like :func:`mamba_block` but also returns the decode-ready caches.

    Returns ``(y (B,S,D), ssm_state (B,H,P,N) fp32, conv_state (B,W-1,C))``
    where the states are exactly what ``mamba_block_decode`` would hold
    after consuming the row's ``length`` tokens one at a time:

    * pad positions get ``dt = 0`` — decay ``exp(0)=1`` and zero input —
      so the recurrence is frozen beyond each row's length;
    * the final state is the closed form of the unrolled recurrence,
      ``h_L = sum_t exp(sum_{s>t} dta_s) * dx_t B_t^T``, one einsum over
      the cumulative-decay weights instead of a sequential scan;
    * the conv window is the last ``W-1`` *raw* (pre-silu) conv inputs
      before the row's length, matching the decode-path layout.
    """
    b, s, d = x.shape
    d_in, n_heads, n_state, conv_dim = _dims(cfg)
    proj_out = 2 * d_in + 2 * n_state + n_heads
    zxbcdt = linear.linear_apply(params["in_proj"], x, d, proj_out, cfg, "ssm_in")
    z, xbc_raw, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)

    # causal depthwise conv over (x, B, C) — identical to the train path
    w = params["conv_w"].astype(x.dtype)
    pad = cfg.conv_width - 1
    xbc_pad = jnp.pad(xbc_raw, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + s, :] * w[i]
        for i in range(cfg.conv_width)
    ) + params["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(conv)

    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)
    xs = xs.reshape(b, s, n_heads, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))               # (H,)
    maskf = mask.astype(jnp.float32)[..., None]                     # (B,S,1)
    dta = (dt * a) * maskf                                          # (B,S,H)
    dx = (xs.astype(jnp.float32) * dt[..., None]) * maskf[..., None]

    # outputs via the parallel chunked SSD (pad S to a chunk multiple with
    # frozen steps: dta=0 -> decay 1, dx=0 -> no contribution)
    chunk = min(cfg.ssm_chunk, max(s, 1))
    s_pad = -(-s // chunk) * chunk
    tpad = ((0, 0), (0, s_pad - s), (0, 0))
    y = ssd_chunked(
        jnp.pad(dx, tpad + ((0, 0),)).astype(x.dtype),
        jnp.pad(dta, tpad),
        jnp.pad(bmat, tpad).astype(x.dtype),
        jnp.pad(cmat, tpad).astype(x.dtype),
        chunk,
        unroll=cfg.scan_unroll,
    )[:, :s]
    y = y + xs * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"]["scale"], cfg.norm_eps)
    y = linear.linear_apply(params["out_proj"], y, d_in, d, cfg, "ssm_out")

    # final SSM state: decay-weighted sum of all (masked) contributions
    a_cum = jnp.cumsum(dta, axis=1)                                 # (B,S,H)
    weight = jnp.exp(a_cum[:, -1:, :] - a_cum) * maskf
    ssm_state = jnp.einsum("bsh,bshp,bsn->bhpn", weight, dx,
                           bmat.astype(jnp.float32) * maskf)

    # conv window: raw inputs at positions [len-W+1, len)
    idx = lengths[:, None] + jnp.arange(-(cfg.conv_width - 1), 0,
                                        dtype=jnp.int32)[None, :]   # (B,W-1)
    valid = (idx >= 0)[..., None]
    idx = jnp.clip(idx, 0, s - 1)
    conv_state = jnp.where(
        valid, jnp.take_along_axis(xbc_raw, idx[..., None], axis=1), 0)
    return y, ssm_state, conv_state


def prefill(
    params: dict,
    cache: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    lengths=None,
    frontend_embeds=None,
) -> Tuple[jax.Array, dict]:
    """Batched prompt pass -> (logits (B,S,V), {"ssm", "conv"} decode cache)."""
    b, s = tokens.shape
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    mask = jnp.arange(s, dtype=jnp.int32)[None, :] < lengths[:, None]
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)

    def body(carry, layer):
        x = carry
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        y, ssm, conv = mamba_block_prefill(layer["mixer"], h, cfg, mask,
                                           lengths)
        return x + y, (ssm, conv)

    x, (new_ssm, new_conv) = jax.lax.scan(body, x, params["layers"],
                                          unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {"ssm": new_ssm.astype(cache["ssm"].dtype),
                    "conv": new_conv.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# Decode path: recurrent state update, O(1) per token.
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype) -> dict:
    d_in, n_heads, n_state, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((n_layers, batch, n_heads, cfg.ssm_head_dim, n_state),
                         jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def mamba_block_decode(
    params: dict,
    x: jax.Array,            # (B, 1, D)
    ssm_state: jax.Array,    # (B, H, P, N) fp32
    conv_state: jax.Array,   # (B, W-1, conv_dim)
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, _, d = x.shape
    d_in, n_heads, n_state, conv_dim = _dims(cfg)
    proj_out = 2 * d_in + 2 * n_state + n_heads
    zxbcdt = linear.linear_apply(params["in_proj"], x, d, proj_out, cfg, "ssm_in")
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xbc = xbc[:, 0]                                    # (B, conv_dim)

    w = params["conv_w"].astype(x.dtype)               # (W, conv_dim)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,W,cd)
    conv = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"].astype(x.dtype)
    new_conv_state = window[:, 1:]
    xbc = jax.nn.silu(conv)

    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)
    xs = xs.reshape(b, n_heads, cfg.ssm_head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))               # (H,)
    decay = jnp.exp(dt * a)                                         # (B,H)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    # h <- decay * h + dt * x B^T ; y = h C
    dx = xs * dt[..., None]                                        # (B,H,P)
    new_state = (ssm_state * decay[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", dx, bmat))
    y = jnp.einsum("bhpn,bn->bhp", new_state, cmat)
    y = y + xs * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"]["scale"], cfg.norm_eps)
    out = linear.linear_apply(params["out_proj"], y, d_in, d, cfg, "ssm_out")
    return out, new_state, new_conv_state


def mamba_block_verify(
    params: dict,
    x: jax.Array,            # (B, T, D)
    ssm_state: jax.Array,    # (B, H, P, N) fp32
    conv_state: jax.Array,   # (B, W-1, conv_dim)
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Consume T tokens sequentially for speculative-decode verification.

    An inner ``lax.scan`` applies :func:`mamba_block_decode` per position —
    bit-identical to T single-token decode steps — and keeps EVERY
    intermediate state: the recurrence cannot rewind like a KV cache, so
    rollback re-commits the state at the accepted length instead.  Returns
    ``(y (B,T,D), ssm_steps (B,T+1,H,P,N), conv_steps (B,T+1,W-1,C))``
    where step index ``j`` is the state after consuming ``j`` tokens
    (index 0 = the incoming state, so zero-advance rows commit cleanly).
    """

    def step(carry, xt):
        ssm, conv = carry
        out, ssm, conv = mamba_block_decode(params, xt[:, None], ssm, conv,
                                            cfg)
        return (ssm, conv), (out[:, 0], ssm, conv)

    _, (ys, ssms, convs) = jax.lax.scan(
        step, (ssm_state, conv_state), jnp.moveaxis(x, 1, 0),
        unroll=cfg.scan_unroll)
    y = jnp.moveaxis(ys, 0, 1)                                  # (B,T,D)
    ssm_steps = jnp.concatenate(
        [ssm_state[:, None], jnp.moveaxis(ssms, 0, 1)], axis=1)
    conv_steps = jnp.concatenate(
        [conv_state[:, None].astype(convs.dtype),
         jnp.moveaxis(convs, 0, 1)], axis=1)
    return y, ssm_steps, conv_steps


# ---------------------------------------------------------------------------
# Full model assembly (decoder of stacked mamba blocks).
# ---------------------------------------------------------------------------

def init(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = cfg.param_dtype
    re, rl = jax.random.split(rng)
    layers = jax.vmap(lambda r: {
        "norm": init_rms_norm(cfg.d_model, dtype),
        "mixer": init_mamba_block(r, cfg, dtype),
    })(jax.random.split(rl, cfg.n_layers))
    return {
        "embed": embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }


def _layer_fn(layer: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
    return x + mamba_block(layer["mixer"], h, cfg)


def apply(params: dict, tokens: jax.Array, cfg: ModelConfig,
          frontend_embeds=None) -> jax.Array:
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)

    fn = _layer_fn
    if cfg.remat:
        fn = jax.checkpoint(_layer_fn,
                            policy=jax.checkpoint_policies.nothing_saveable,
                            static_argnums=(2,))

    def body(carry, layer):
        return fn(layer, carry, cfg), None

    x, _ = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits = apply(params, batch["tokens"], cfg)
    return cross_entropy(logits, batch["labels"], cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    del max_len  # state is O(1) in sequence length
    return init_ssm_cache(cfg, batch, cfg.n_layers, cfg.compute_dtype)


#: cache leaves that are truly recurrent (cannot rewind): speculative
#: rollback re-commits them at the accepted length via per-step snapshots.
RECURRENT_CACHE_KEYS = ("ssm", "conv")


def verify_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T) pending token + k draft tokens
    position: jax.Array,      # (B,) unused: recurrent state carries time
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict, dict]:
    """Speculative append-and-score for the pure-SSM family.

    Returns ``(logits (B,T,V), cache_advanced, states)`` where ``states``
    stacks per-position recurrent snapshots — ``states[key]`` is
    ``cache[key]`` with a ``T+1`` time axis inserted after the batch axis
    (index ``j`` = state after ``j`` consumed tokens).  The caller selects
    the accepted index; ``cache_advanced`` carries the fully-consumed
    state for callers (the draft loop) that always advance by T.
    """
    del position
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)            # (B,T,D)

    def body(carry, xs):
        x = carry
        layer, ssm, conv = xs
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        out, ssm_steps, conv_steps = mamba_block_verify(
            layer["mixer"], h, ssm, conv, cfg)
        return x + out, (ssm_steps, conv_steps)

    x, (ssm_steps, conv_steps) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    states = {"ssm": ssm_steps, "conv": conv_steps}             # (L,B,T+1,..)
    cache = {"ssm": ssm_steps[:, :, -1], "conv": conv_steps[:, :, -1]}
    return logits, cache, states


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                position: jax.Array, cfg: ModelConfig):
    del position  # recurrent state carries time
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens[:, None], dtype)

    def body(carry, xs):
        x = carry
        layer, ssm, conv = xs
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        out, ssm, conv = mamba_block_decode(layer["mixer"], h, ssm, conv, cfg)
        return x + out, (ssm, conv)

    x, (new_ssm, new_conv) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, {"ssm": new_ssm, "conv": new_conv}
