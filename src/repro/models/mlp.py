"""Gated MLP (SwiGLU/GeGLU) and Mixture-of-Experts feed-forward layers.

The MoE path implements fine-grained expert FFNs with shared experts
(DeepSeekMoE / Moonlight style: e.g. 64 routed top-6 + 2 shared) using the
capacity-based einsum dispatch that shards cleanly under pjit:

    router probs -> top-k -> position-in-expert -> dispatch one-hot
    (tokens, E, C) -> expert matmuls (E, C, ...) -> combine

Expert weights carry a leading E axis that the sharding rules map to the
``model`` mesh axis (expert parallelism); the dispatch einsum lowers to an
all-to-all under pjit.

When ``sell_targets`` contains ``"expert"`` the per-expert FFN matrices are
replaced by per-expert ACDC cascades (vmapped over E) — the paper's layer
applied where the parameter mass of an MoE actually lives.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import linear
from repro.models.common import ModelConfig


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# Dense gated MLP.
# ---------------------------------------------------------------------------

def init_mlp(rng: jax.Array, cfg: ModelConfig, d_ff: Optional[int] = None,
             dtype=jnp.float32) -> dict:
    d_ff = d_ff or cfg.d_ff
    rg, ru, rd = jax.random.split(rng, 3)
    return {
        "wg": linear.linear_init(rg, cfg.d_model, d_ff, cfg, "mlp_in", dtype),
        "wu": linear.linear_init(ru, cfg.d_model, d_ff, cfg, "mlp_in", dtype),
        "wd": linear.linear_init(rd, d_ff, cfg.d_model, cfg, "mlp_out", dtype),
    }


def mlp(params: dict, x: jax.Array, cfg: ModelConfig,
        d_ff: Optional[int] = None) -> jax.Array:
    d_ff = d_ff or cfg.d_ff
    g = linear.linear_apply(params["wg"], x, cfg.d_model, d_ff, cfg, "mlp_in")
    u = linear.linear_apply(params["wu"], x, cfg.d_model, d_ff, cfg, "mlp_in")
    h = _act(cfg.mlp_act)(g) * u
    return linear.linear_apply(params["wd"], h, d_ff, cfg.d_model, cfg, "mlp_out")


# ---------------------------------------------------------------------------
# Mixture of Experts.
# ---------------------------------------------------------------------------

def init_moe(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    e = cfg.n_experts
    rr, re, rs = jax.random.split(rng, 3)
    p = {
        "router": {"w": (cfg.d_model ** -0.5) * jax.random.normal(
            rr, (cfg.d_model, e), dtype)},
        # routed experts: stacked with leading E axis (expert-parallel)
        "experts": jax.vmap(
            lambda r: init_mlp(r, cfg, cfg.d_ff, dtype)
        )(jax.random.split(re, e)),
    }
    if cfg.n_shared_experts > 0:
        shared_ff = cfg.d_ff * cfg.n_shared_experts
        p["shared"] = init_mlp(rs, cfg, shared_ff, dtype)
    return p


def _expert_ffn(wp: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """h: (E, C, D) with per-expert stacked weights."""
    def one(w, hh):
        return mlp(w, hh, cfg, cfg.d_ff)
    return jax.vmap(one)(wp, h)


def _route(xt: jax.Array, params: dict, cfg: ModelConfig):
    """Shared router math -> (gate_vals, gate_idx, pos, keep, cap)."""
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * t * k / e), 1)
    logits = jnp.matmul(xt.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                    # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)          # (T,k,E)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                   # (T, k)
    keep = pos < cap                                                 # capacity drop
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    return gate_vals, gate_idx, pos.astype(jnp.int32), keep, cap, onehot


def _moe_einsum(params, xt, cfg, gate_vals, gate_idx, pos, keep, cap,
                onehot):
    """Faithful GShard/Switch one-hot dispatch: O(T*E*C*d) einsums."""
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)
    dispatch = jnp.einsum("tke,tkc->tec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("tke,tkc->tec", onehot * gate_vals[..., None], pos_oh)
    h = jnp.einsum("td,tec->ecd", xt.astype(jnp.float32), dispatch)
    h = h.astype(xt.dtype)
    y_exp = _expert_ffn(params["experts"], h, cfg)                   # (E, C, D)
    y = jnp.einsum("ecd,tec->td", y_exp.astype(jnp.float32), combine)
    return y.astype(xt.dtype)


def _moe_scatter(params, xt, cfg, gate_vals, gate_idx, pos, keep, cap):
    """Scatter/gather dispatch: O(T*k*d) data movement, no (T,E,C) tensors.

    The one-hot dispatch einsum costs 2*T*E*C*d FLOPs — QUADRATIC in tokens
    (C ~ T*k/E) and ~12x the useful expert FLOPs at the assigned MoE shapes
    (baseline useful/HLO ratio 0.08, EXPERIMENTS.md section Perf hillclimb
    #3).  Scatter-add into the (E*C, d) buffer and gather back are linear.
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    dest = gate_idx * cap + pos                                     # (T, k)
    dest = jnp.where(keep, dest, e * cap)                           # drop slot
    buf = jnp.zeros((e * cap + 1, d), jnp.float32)
    src = jnp.broadcast_to(xt.astype(jnp.float32)[:, None, :],
                           (t, k, d)).reshape(-1, d)
    buf = buf.at[dest.reshape(-1)].add(src)
    h = buf[: e * cap].reshape(e, cap, d).astype(xt.dtype)
    y_exp = _expert_ffn(params["experts"], h, cfg)                  # (E, C, D)
    flat = jnp.concatenate(
        [y_exp.reshape(e * cap, d).astype(jnp.float32),
         jnp.zeros((1, d), jnp.float32)], axis=0)
    gathered = flat[dest]                                           # (T, k, D)
    y = jnp.sum(gathered * gate_vals[..., None], axis=1)
    return y.astype(xt.dtype)


def moe(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). Capacity-based top-k dispatch."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gate_vals, gate_idx, pos, keep, cap, onehot = _route(xt, params, cfg)
    if cfg.moe_impl == "scatter":
        y = _moe_scatter(params, xt, cfg, gate_vals, gate_idx, pos, keep, cap)
    else:
        y = _moe_einsum(params, xt, cfg, gate_vals, gate_idx, pos, keep,
                        cap, onehot)
    y = y.reshape(b, s, d)
    if "shared" in params:
        y = y + mlp(params["shared"], x, cfg,
                    cfg.d_ff * cfg.n_shared_experts)
    return y


def moe_aux_loss(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style f*P)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.matmul(xt.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
