"""Decoder-only transformer LM assembly.

Covers seven of the ten assigned architectures via config knobs:
deepseek-67b, chatglm3-6b, gemma3-27b, qwen3-1.7b, moonshot-v1-16b-a3b,
deepseek-moe-16b, and the llava-next-34b backbone (vision-stub prefix).

Layer parameters are stacked (leading L axis) and the layer loop is a
``lax.scan`` so the compiled program is O(1) in depth; per-layer
heterogeneity (gemma3's 5:1 local:global windows) rides along as a scanned
int32 array.  ``cfg.remat`` wraps the layer body in ``jax.checkpoint`` with
a policy that saves only the residual stream.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import (
    ModelConfig,
    cross_entropy,
    embed_init,
    embed_lookup,
    init_rms_norm,
    rms_norm,
    unembed,
)


def init_layer(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ra, rm = jax.random.split(rng)
    p = {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(ra, cfg, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
    }
    if cfg.n_experts > 0:
        p["moe"] = mlp_mod.init_moe(rm, cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp(rm, cfg, None, dtype)
    return p


def init(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = cfg.param_dtype
    re, rl, rf = jax.random.split(rng, 3)
    layers = jax.vmap(lambda r: init_layer(r, cfg, dtype))(
        jax.random.split(rl, cfg.n_layers))
    params = {
        "embed": embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    return params


def _layer_fn(layer: dict, x: jax.Array, positions: jax.Array,
              window: jax.Array, cfg: ModelConfig):
    h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
    x = x + attn_mod.attention(layer["attn"], h, positions, window, cfg)
    h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
    if "moe" in layer:
        aux = mlp_mod.moe_aux_loss(layer["moe"], h, cfg)
        x = x + mlp_mod.moe(layer["moe"], h, cfg)
    else:
        aux = jnp.zeros((), jnp.float32)
        x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
    return x, aux


def backbone(params: dict, x: jax.Array, positions: jax.Array,
             cfg: ModelConfig):
    """Run the stacked layers over ``x`` (B, S, D) -> (hidden, mean aux)."""
    windows = jnp.asarray(cfg.layer_windows())

    fn = _layer_fn
    if cfg.remat:
        fn = jax.checkpoint(
            _layer_fn,
            policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(4,),
        )

    def body(carry, xs):
        layer, window = xs
        return fn(layer, carry, positions, window, cfg)

    x, aux = jax.lax.scan(body, x, (params["layers"], windows),
                          unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x, jnp.mean(aux)


def apply(
    params: dict,
    tokens: jax.Array,                       # (B, S) int32
    cfg: ModelConfig,
    frontend_embeds: Optional[jax.Array] = None,  # (B, P, D) vision stub
) -> jax.Array:
    """Training/prefill forward -> fp32 logits (B, S, V)."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    if frontend_embeds is not None:
        p = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(dtype), x[:, p:]], axis=1)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = backbone(params, x, positions, cfg)
    return unembed(params["embed"], x)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    """Next-token cross-entropy; positions with label < 0 are masked."""
    dtype = cfg.compute_dtype
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens, dtype)
    fe = batch.get("frontend_embeds")
    if fe is not None:
        p = fe.shape[1]
        x = jnp.concatenate([fe.astype(dtype), x[:, p:]], axis=1)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, aux = backbone(params, x, positions, cfg)
    logits = unembed(params["embed"], x)
    loss = cross_entropy(logits, batch["labels"], cfg)
    if cfg.n_experts > 0:
        loss = loss + 0.01 * aux
    return loss


# ---------------------------------------------------------------------------
# Decode (one token per step, KV cache).
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return attn_mod.init_kv_cache(cfg, batch, max_len, cfg.n_layers,
                                  cfg.compute_dtype)


def init_cache_paged(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int) -> dict:
    """Paged decode cache: one global page pool shared by all slots
    (``batch`` is unused here — KV is the only state and it is pooled)."""
    del batch
    return attn_mod.init_kv_cache_paged(cfg, n_blocks, block_size,
                                        cfg.n_layers, cfg.compute_dtype)


def prefill(
    params: dict,
    cache: dict,
    tokens: jax.Array,                       # (B, S) right-padded prompts
    cfg: ModelConfig,
    lengths: Optional[jax.Array] = None,     # (B,) valid length per row
    frontend_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """One lowered forward over the whole prompt -> (logits (B,S,V), cache).

    The layer scan mirrors :func:`backbone` but keeps each layer's K/V and
    scatters them into the cache slab (positions >= the row's length are
    zeroed; see :func:`repro.models.attention.scatter_prefill_kv`).  With
    right padding the causal mask already keeps pad tokens out of every
    real position's context, so ragged batches need no extra masking here.
    """
    b, s = tokens.shape
    smax = cache["k"].shape[2]
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    if frontend_embeds is not None:
        p = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(dtype), x[:, p:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        x = carry
        layer, window = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, k, v = attn_mod.attention_prefill(layer["attn"], h, positions,
                                               window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        if "moe" in layer:
            x = x + mlp_mod.moe(layer["moe"], h, cfg)
        else:
            x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        ck, cv = attn_mod.scatter_prefill_kv(k, v, lengths, smax)
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(body, x, (params["layers"], windows),
                                     unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {"k": new_k.astype(cache["k"].dtype),
                    "v": new_v.astype(cache["v"].dtype)}


def decode_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B,) current token ids
    position: jax.Array,      # (B,) current position
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict]:
    """One decode step -> (logits (B, V), updated cache)."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens[:, None], dtype)  # (B,1,D)
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        x = carry
        layer, window, ck, cv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, ck, cv = attn_mod.attention_decode(
            layer["attn"], h, ck, cv, position, window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        if "moe" in layer:
            x = x + mlp_mod.moe(layer["moe"], h, cfg)
        else:
            x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, {"k": new_k, "v": new_v}


def verify_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T) pending token + k draft tokens
    position: jax.Array,      # (B,) first write position per row
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict, None]:
    """Speculative-decode append-and-score: T tokens in ONE lowered pass.

    Returns ``(logits (B, T, V), cache, None)`` — logits at row position
    ``i`` score the token that follows ``tokens[:, i]``, exactly what
    ``decode_step`` would emit feeding the same tokens one at a time.  K/V
    is set-written (:func:`repro.models.attention.attention_verify`), so
    rejected tail positions roll back by rewinding ``position``; the KV
    cache needs no state selection (trailing ``None``).
    """
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)          # (B,T,D)
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        x = carry
        layer, window, ck, cv = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, ck, cv = attn_mod.attention_verify(
            layer["attn"], h, ck, cv, position, window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        if "moe" in layer:
            x = x + mlp_mod.moe(layer["moe"], h, cfg)
        else:
            x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {"k": new_k, "v": new_v}, None


def verify_step_paged(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T)
    position: jax.Array,      # (B,)
    block_tables: jax.Array,  # (B, MB) int32, -1 = unmapped
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict, None]:
    """Paged twin of :func:`verify_step` (writes through the block table)."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dtype)          # (B,T,D)
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        x = carry
        layer, window, kp, vp = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, kp, vp = attn_mod.attention_verify_paged(
            layer["attn"], h, kp, vp, block_tables, position, window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        if "moe" in layer:
            x = x + mlp_mod.moe(layer["moe"], h, cfg)
        else:
            x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (kp, vp)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k_pages"],
                  cache["v_pages"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {"k_pages": new_k, "v_pages": new_v}, None


def decode_step_paged(
    params: dict,
    cache: dict,              # {"k_pages", "v_pages"}: (L, NB+1, bs, Hkv, Dh)
    tokens: jax.Array,        # (B,) current token ids
    position: jax.Array,      # (B,) current position
    block_tables: jax.Array,  # (B, MB) int32, -1 = unmapped
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict]:
    """One decode step against the paged KV pool -> (logits (B, V), cache)."""
    dtype = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens[:, None], dtype)  # (B,1,D)
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        x = carry
        layer, window, kp, vp = xs
        h = rms_norm(x, layer["norm1"]["scale"], cfg.norm_eps)
        out, kp, vp = attn_mod.attention_decode_paged(
            layer["attn"], h, kp, vp, block_tables, position, window, cfg)
        x = x + out
        h = rms_norm(x, layer["norm2"]["scale"], cfg.norm_eps)
        if "moe" in layer:
            x = x + mlp_mod.moe(layer["moe"], h, cfg)
        else:
            x = x + mlp_mod.mlp(layer["mlp"], h, cfg)
        return x, (kp, vp)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k_pages"],
                  cache["v_pages"]),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, {"k_pages": new_k, "v_pages": new_v}
