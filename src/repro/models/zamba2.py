"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block
(arXiv:2411.15242).

A single transformer block's parameters are reused at every ``attn_every``-th
position in the Mamba2 stack (Zamba's parameter-sharing trick).  As in the
paper, the shared block sees the concatenation of the current hidden state
and the original embedding; we fold that through a 2d->d input projection.

The structure composes with the ACDC SELL naturally: the shared block's
projections and the mamba in/out projections both route through the SELL
factory (shared structured weights = double savings).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import linear
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models.common import (
    ModelConfig,
    cross_entropy,
    embed_init,
    embed_lookup,
    init_rms_norm,
    rms_norm,
    unembed,
)


def _n_groups(cfg: ModelConfig):
    k = cfg.attn_every
    full, rem = divmod(cfg.n_layers, k)
    sizes = [k] * full + ([rem] if rem else [])
    return sizes


def init(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = cfg.param_dtype
    re, rl, rs, rp = jax.random.split(rng, 4)
    layers = jax.vmap(lambda r: {
        "norm": init_rms_norm(cfg.d_model, dtype),
        "mixer": mamba_mod.init_mamba_block(r, cfg, dtype),
    })(jax.random.split(rl, cfg.n_layers))
    d = cfg.d_model
    shared = {
        "in_proj": linear.linear_init(rp, 2 * d, d, cfg, "shared_in", dtype),
        "norm1": init_rms_norm(d, dtype),
        "attn": attn_mod.init_attention(rs, cfg, dtype),
        "norm2": init_rms_norm(d, dtype),
        "mlp": mlp_mod.init_mlp(jax.random.fold_in(rs, 1), cfg, None, dtype),
    }
    return {
        "embed": embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "shared": shared,
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }


def _shared_block(shared: dict, x: jax.Array, emb: jax.Array,
                  positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    d = cfg.d_model
    h = linear.linear_apply(shared["in_proj"],
                            jnp.concatenate([x, emb], axis=-1),
                            2 * d, d, cfg, "shared_in")
    a = rms_norm(h, shared["norm1"]["scale"], cfg.norm_eps)
    window = jnp.zeros((), jnp.int32)  # full attention
    h = h + attn_mod.attention(shared["attn"], a, positions, window, cfg)
    m = rms_norm(h, shared["norm2"]["scale"], cfg.norm_eps)
    h = h + mlp_mod.mlp(shared["mlp"], m, cfg)
    return x + h


def apply(params: dict, tokens: jax.Array, cfg: ModelConfig,
          frontend_embeds=None) -> jax.Array:
    dtype = cfg.compute_dtype
    emb = embed_lookup(params["embed"], tokens, dtype)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    fn = mamba_mod._layer_fn
    if cfg.remat:
        fn = jax.checkpoint(mamba_mod._layer_fn,
                            policy=jax.checkpoint_policies.nothing_saveable,
                            static_argnums=(2,))

    def body(carry, layer):
        return fn(layer, carry, cfg), None

    x = emb
    start = 0
    for size in _n_groups(cfg):
        group = jax.tree.map(lambda p: p[start : start + size], params["layers"])
        x, _ = jax.lax.scan(body, x, group, unroll=cfg.scan_unroll)
        x = _shared_block(params["shared"], x, emb, positions, cfg)
        start += size
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits = apply(params, batch["tokens"], cfg)
    return cross_entropy(logits, batch["labels"], cfg)


# ---------------------------------------------------------------------------
# Prefill: batched prompt pass filling SSM states + shared-block KV.
# ---------------------------------------------------------------------------

def prefill(params: dict, cache: dict, tokens: jax.Array, cfg: ModelConfig,
            lengths=None, frontend_embeds=None):
    """Mirror of :func:`apply` that keeps every decode cache: per-layer SSM
    and conv states from the mamba groups, plus K/V for each application of
    the shared attention block -> (logits (B,S,V), cache)."""
    b, s = tokens.shape
    smax = cache["attn_k"].shape[2]
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    mask = jnp.arange(s, dtype=jnp.int32)[None, :] < lengths[:, None]
    dtype = cfg.compute_dtype
    emb = embed_lookup(params["embed"], tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    window = jnp.zeros((), jnp.int32)
    d = cfg.d_model

    def body(carry, layer):
        x = carry
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        y, ssm, conv = mamba_mod.mamba_block_prefill(layer["mixer"], h, cfg,
                                                     mask, lengths)
        return x + y, (ssm, conv)

    x = emb
    start = 0
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    for size in _n_groups(cfg):
        group = jax.tree.map(lambda p: p[start : start + size],
                             params["layers"])
        x, (ssm, conv) = jax.lax.scan(body, x, group, unroll=cfg.scan_unroll)
        new_ssm.append(ssm)
        new_conv.append(conv)
        # shared attention application, keeping its K/V
        h = linear.linear_apply(params["shared"]["in_proj"],
                                jnp.concatenate([x, emb], axis=-1),
                                2 * d, d, cfg, "shared_in")
        a = rms_norm(h, params["shared"]["norm1"]["scale"], cfg.norm_eps)
        out, k, v = attn_mod.attention_prefill(params["shared"]["attn"], a,
                                               positions, window, cfg)
        h = h + out
        m = rms_norm(h, params["shared"]["norm2"]["scale"], cfg.norm_eps)
        h = h + mlp_mod.mlp(params["shared"]["mlp"], m, cfg)
        x = x + h
        ck, cv = attn_mod.scatter_prefill_kv(k, v, lengths, smax)
        new_k.append(ck)
        new_v.append(cv)
        start += size

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {
        "ssm": jnp.concatenate(new_ssm, axis=0).astype(cache["ssm"].dtype),
        "conv": jnp.concatenate(new_conv, axis=0).astype(cache["conv"].dtype),
        "attn_k": jnp.stack(new_k, axis=0).astype(cache["attn_k"].dtype),
        "attn_v": jnp.stack(new_v, axis=0).astype(cache["attn_v"].dtype),
    }


# ---------------------------------------------------------------------------
# Decode: mamba states + KV caches for each shared-block application.
# ---------------------------------------------------------------------------

#: cache leaves that are truly recurrent (cannot rewind): speculative
#: rollback re-commits them at the accepted length via per-step snapshots,
#: and the paged decode freezes them on stalled (parked) rows.
RECURRENT_CACHE_KEYS = ("ssm", "conv")

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_apps = len(_n_groups(cfg))
    cache = mamba_mod.init_ssm_cache(cfg, batch, cfg.n_layers, cfg.compute_dtype)
    kv = attn_mod.init_kv_cache(cfg, batch, max_len, n_apps, cfg.compute_dtype)
    cache["attn_k"] = kv["k"]
    cache["attn_v"] = kv["v"]
    return cache


def init_cache_paged(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int) -> dict:
    """Page only the shared-attention KV — the length-proportional state.
    Mamba SSM/conv state is O(1) per slot regardless of sequence length,
    so it stays dense (there is no worst-case-length slab to reclaim)."""
    n_apps = len(_n_groups(cfg))
    cache = mamba_mod.init_ssm_cache(cfg, batch, cfg.n_layers,
                                     cfg.compute_dtype)
    kv = attn_mod.init_kv_cache_paged(cfg, n_blocks, block_size, n_apps,
                                      cfg.compute_dtype)
    cache["attn_k_pages"] = kv["k_pages"]
    cache["attn_v_pages"] = kv["v_pages"]
    return cache


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                position: jax.Array, cfg: ModelConfig):
    dtype = cfg.compute_dtype
    emb = embed_lookup(params["embed"], tokens[:, None], dtype)

    def body(carry, xs):
        x = carry
        layer, ssm, conv = xs
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        out, ssm, conv = mamba_mod.mamba_block_decode(
            layer["mixer"], h, ssm, conv, cfg)
        return x + out, (ssm, conv)

    x = emb
    start = 0
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    window = jnp.zeros((), jnp.int32)
    for app, size in enumerate(_n_groups(cfg)):
        sl = lambda p: p[start : start + size]
        group = (jax.tree.map(sl, params["layers"]),
                 cache["ssm"][start : start + size],
                 cache["conv"][start : start + size])
        x, (ssm, conv) = jax.lax.scan(body, x, group,
                                      unroll=cfg.scan_unroll)
        new_ssm.append(ssm)
        new_conv.append(conv)
        # shared attention application `app`
        d = cfg.d_model
        h = linear.linear_apply(params["shared"]["in_proj"],
                                jnp.concatenate([x, emb], axis=-1),
                                2 * d, d, cfg, "shared_in")
        a = rms_norm(h, params["shared"]["norm1"]["scale"], cfg.norm_eps)
        out, ck, cv = attn_mod.attention_decode(
            params["shared"]["attn"], a,
            cache["attn_k"][app], cache["attn_v"][app],
            position, window, cfg)
        h = h + out
        m = rms_norm(h, params["shared"]["norm2"]["scale"], cfg.norm_eps)
        h = h + mlp_mod.mlp(params["shared"]["mlp"], m, cfg)
        x = x + h
        new_k.append(ck)
        new_v.append(cv)
        start += size

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
        "attn_k": jnp.stack(new_k, axis=0),
        "attn_v": jnp.stack(new_v, axis=0),
    }


def _verify_impl(params, cache, tokens, position, cfg, attend):
    """Shared speculative append-and-score body (dense / paged shared
    attention differ only in ``attend``).  Returns ``(logits (B,T,V),
    kv_leaves, states)`` with ``states`` the per-position recurrent
    snapshots: each leaf is the cache leaf with a ``T+1`` time axis after
    the batch axis (index j = state after j consumed tokens)."""
    dtype = cfg.compute_dtype
    emb = embed_lookup(params["embed"], tokens, dtype)          # (B,T,D)
    d = cfg.d_model

    def body(carry, xs):
        x = carry
        layer, ssm, conv = xs
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        out, ssm_steps, conv_steps = mamba_mod.mamba_block_verify(
            layer["mixer"], h, ssm, conv, cfg)
        return x + out, (ssm_steps, conv_steps)

    x = emb
    start = 0
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    for app, size in enumerate(_n_groups(cfg)):
        sl = lambda p: p[start : start + size]
        group = (jax.tree.map(sl, params["layers"]),
                 cache["ssm"][start : start + size],
                 cache["conv"][start : start + size])
        x, (ssm_steps, conv_steps) = jax.lax.scan(body, x, group,
                                                  unroll=cfg.scan_unroll)
        new_ssm.append(ssm_steps)
        new_conv.append(conv_steps)
        # shared attention application `app`
        h = linear.linear_apply(params["shared"]["in_proj"],
                                jnp.concatenate([x, emb], axis=-1),
                                2 * d, d, cfg, "shared_in")
        a = rms_norm(h, params["shared"]["norm1"]["scale"], cfg.norm_eps)
        out, ck, cv = attend(app, a)
        h = h + out
        m = rms_norm(h, params["shared"]["norm2"]["scale"], cfg.norm_eps)
        h = h + mlp_mod.mlp(params["shared"]["mlp"], m, cfg)
        x = x + h
        new_k.append(ck)
        new_v.append(cv)
        start += size

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    states = {"ssm": jnp.concatenate(new_ssm, axis=0),          # (L,B,T+1,..)
              "conv": jnp.concatenate(new_conv, axis=0)}
    return logits, (jnp.stack(new_k, axis=0), jnp.stack(new_v, axis=0)), states


def verify_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T) pending token + k draft tokens
    position: jax.Array,      # (B,) first write position per row
    cfg: ModelConfig,
):
    """Speculative append-and-score: shared-attention KV set-written at
    ``position + i`` (rollback = position rewind), Mamba SSM/conv state
    snapshotted per position in ``states`` for accepted-length commit."""
    window = jnp.zeros((), jnp.int32)

    def attend(app, a):
        return attn_mod.attention_verify(
            params["shared"]["attn"], a, cache["attn_k"][app],
            cache["attn_v"][app], position, window, cfg)

    logits, (nk, nv), states = _verify_impl(params, cache, tokens, position,
                                            cfg, attend)
    new_cache = {"ssm": states["ssm"][:, :, -1],
                 "conv": states["conv"][:, :, -1],
                 "attn_k": nk, "attn_v": nv}
    return logits, new_cache, states


def verify_step_paged(
    params: dict,
    cache: dict,
    tokens: jax.Array,        # (B, T)
    position: jax.Array,      # (B,)
    block_tables: jax.Array,  # (B, MB)
    cfg: ModelConfig,
):
    """Paged twin of :func:`verify_step`: shared-attention KV set-scattered
    through the block table; SSM/conv snapshots identical to dense."""
    window = jnp.zeros((), jnp.int32)

    def attend(app, a):
        return attn_mod.attention_verify_paged(
            params["shared"]["attn"], a, cache["attn_k_pages"][app],
            cache["attn_v_pages"][app], block_tables, position, window, cfg)

    logits, (nk, nv), states = _verify_impl(params, cache, tokens, position,
                                            cfg, attend)
    new_cache = {"ssm": states["ssm"][:, :, -1],
                 "conv": states["conv"][:, :, -1],
                 "attn_k_pages": nk, "attn_v_pages": nv}
    return logits, new_cache, states


def decode_step_paged(params: dict, cache: dict, tokens: jax.Array,
                      position: jax.Array, block_tables: jax.Array,
                      cfg: ModelConfig):
    """Mirror of :func:`decode_step` with each shared-attention application
    reading/writing its own paged KV pool; SSM/conv state stays dense.

    Rows parked at/beyond the virtual row length (free slots AND slots the
    engine stalled because the page pool ran dry) FREEZE their SSM/conv
    state: a stalled slot's pending token is re-issued once the stall
    clears, and the recurrence — unlike the KV write, which the table
    routes to the trash page — would otherwise consume it twice."""
    dtype = cfg.compute_dtype
    emb = embed_lookup(params["embed"], tokens[:, None], dtype)

    def body(carry, xs):
        x = carry
        layer, ssm, conv = xs
        h = rms_norm(x, layer["norm"]["scale"], cfg.norm_eps)
        out, ssm, conv = mamba_mod.mamba_block_decode(
            layer["mixer"], h, ssm, conv, cfg)
        return x + out, (ssm, conv)

    x = emb
    start = 0
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    window = jnp.zeros((), jnp.int32)
    for app, size in enumerate(_n_groups(cfg)):
        sl = lambda p: p[start : start + size]
        group = (jax.tree.map(sl, params["layers"]),
                 cache["ssm"][start : start + size],
                 cache["conv"][start : start + size])
        x, (ssm, conv) = jax.lax.scan(body, x, group,
                                      unroll=cfg.scan_unroll)
        new_ssm.append(ssm)
        new_conv.append(conv)
        # shared attention application `app`
        d = cfg.d_model
        h = linear.linear_apply(params["shared"]["in_proj"],
                                jnp.concatenate([x, emb], axis=-1),
                                2 * d, d, cfg, "shared_in")
        a = rms_norm(h, params["shared"]["norm1"]["scale"], cfg.norm_eps)
        out, kp, vp = attn_mod.attention_decode_paged(
            params["shared"]["attn"], a,
            cache["attn_k_pages"][app], cache["attn_v_pages"][app],
            block_tables, position, window, cfg)
        h = h + out
        m = rms_norm(h, params["shared"]["norm2"]["scale"], cfg.norm_eps)
        h = h + mlp_mod.mlp(params["shared"]["mlp"], m, cfg)
        x = x + h
        new_k.append(kp)
        new_v.append(vp)
        start += size

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    bs = cache["attn_k_pages"].shape[2]
    parked = (position >= block_tables.shape[1] * bs)[None, :]  # (1, B)
    ssm = jnp.concatenate(new_ssm, axis=0)
    conv = jnp.concatenate(new_conv, axis=0)
    return logits, {
        "ssm": jnp.where(parked[..., None, None, None], cache["ssm"], ssm),
        "conv": jnp.where(parked[..., None, None], cache["conv"], conv),
        "attn_k_pages": jnp.stack(new_k, axis=0),
        "attn_v_pages": jnp.stack(new_v, axis=0),
    }
