"""Unified observability: metrics registry, span tracing, profiler hooks.

Three pieces, one bundle (:class:`Observability`), threaded through the
serving engine, the kernels' dispatch counters, and both launchers:

* :mod:`repro.obs.metrics` — typed, labeled Counter / Gauge / Histogram
  registry with ``snapshot()`` / merge / JSON-lines export / Prometheus
  text exposition.  ``Engine.stats`` is a back-compat
  :class:`~repro.obs.metrics.StatsView` over a per-engine registry, and
  the kernel dispatch-counter globals are dict-shims over the
  process-global ``REGISTRY`` — one implementation behind every
  existing name.
* :mod:`repro.obs.trace` — per-request lifecycle span tracing over an
  injectable monotonic clock (virtual-clock compatible), exported as
  Chrome/Perfetto trace-event JSON.
* :mod:`repro.obs.prof` — ``jax.profiler`` named-scope annotations
  around the engine's prefill/draft/verify/decode dispatches and an
  on-demand capture window (``--profile-ticks A:B``).

The noop fast path (default)
----------------------------
Observability is OFF by default and must cost nothing measurable:

* the engine always owns a registry (it IS ``Engine.stats`` — counters
  were always on), so "off" only disables the optional surfaces;
* every trace-emission site in the engine is guarded by one
  ``self._tracer is not None`` check (bound once in ``__init__``);
* ``Prof.annotate`` returns one shared ``contextlib.nullcontext`` —
  no allocation, no jax call;
* the per-tick exporter/profile-window hook is ``None`` when neither is
  configured, so the tick loop pays a single attribute test.

``tests/test_obs.py`` pins this down twice: a structural check (engine
with ``Observability.off()`` binds no tracer/exporter/hook) and a
token-identity check (greedy streams with obs on == obs off == the
pre-obs engine).

Metric name glossary
--------------------
Engine registry (one per :class:`~repro.serving.engine.Engine`; the
``Engine.stats`` key for each lives in
``repro.serving.engine.STATS_METRICS`` and the cross-reference table in
``repro/serving/__init__.py``):

==================================  =========  ================================
name                                kind       meaning
==================================  =========  ================================
serve_prefill_dispatches_total      counter    admission prefill programs run
serve_decode_ticks_total            counter    fused decode/verify ticks
serve_tokens_out_total              counter    tokens committed to requests
serve_finished_total                counter    requests reaching terminal state
serve_preempted_total               counter    preemptions (all causes)
serve_requeued_total                counter    preempt-with-requeue recoveries
serve_timeout_total                 counter    deadline expiries (queued+active)
serve_rejected_total                counter    shed by the bounded queue
serve_deadline_preempts_total       counter    preemptions forced by deadlines
serve_corrupt_ticks_total           counter    FaultPlan corrupt-logit ticks
serve_stalled_slot_ticks_total      counter    slot-ticks parked on a dry pool
serve_degrade_down_total            counter    ladder steps down
serve_degrade_up_total              counter    ladder steps up
serve_degrade_level                 gauge      current ladder rung index
serve_prefill_seconds_total         counter    wall seconds in prefill dispatch
serve_decode_seconds_total          counter    wall seconds in decode dispatch
serve_spec_drafted_total            counter    draft tokens proposed
serve_spec_accepted_total           counter    draft tokens accepted
serve_acceptance_rate               derived    accepted/drafted AT SNAPSHOT
                                               time (never stale)
serve_attn_gather_bytes_total       counter    analytic gather-path attn bytes
serve_attn_kernel_bytes_total       counter    analytic fused-path attn bytes
serve_ttft_seconds                  histogram  submit -> first token
serve_tpot_seconds                  histogram  per-token decode latency
                                               (finish-ttft)/(n_tokens-1)
serve_tick_seconds                  histogram  engine tick wall latency
==================================  =========  ================================

Process-global ``REGISTRY`` (kernels, autotune, training):

====================================  =========  ==============================
kernel_cascade_bwd_dispatches_total   counter    label route=reverse_sweep|
                                                 per_layer_scan (trace-time)
kernel_paged_attn_dispatches_total    counter    label route=fused|gather
autotune_sweeps_total                 counter    label direction=...; completed
                                                 on-device block-size sweeps
straggler_flags_total                 counter    StragglerMonitor flags
train_step_loss                       gauge      last step loss
train_tokens_per_s                    gauge      last step token throughput
train_grad_compressed_bytes           gauge      int8 wire bytes per step
train_grad_raw_bytes                  gauge      fp32 equivalent per step
train_cascade_diag_norm               gauge      labels param=a|d, cascade=
                                                 <path>; per-cascade ||.||_2
train_step_seconds                    histogram  step wall time
====================================  =========  ==============================

Span / event name glossary (:mod:`repro.obs.trace`)
---------------------------------------------------
Request tracks (``req <rid>``) — phase spans: ``queued``, ``prefill``,
``decode``, ``backoff`` (post-preemption wait); instants: ``preempt``
(args: cause), exactly one ``terminal:<finish_reason>`` per request
(``finish_reason`` one of :data:`repro.serving.request.FinishReason.ALL`).
Engine track (``engine``) — instants: ``ladder`` (args: from/to rung,
direction), ``deadline_preempt``, ``straggler``, ``fault:corrupt_logits``,
``fault:spurious_stall``, ``fault:slow_tick``.  Global-hook tracks:
``allocator`` (``audit``), ``autotune`` (``sweep`` with direction/key/
winner), ``train`` (``straggler``).
"""

from __future__ import annotations

from typing import Optional

from repro.obs.metrics import (  # noqa: F401
    REGISTRY,
    Counter,
    CounterDict,
    Gauge,
    Histogram,
    JsonlExporter,
    Registry,
    StatsView,
    merge_snapshots,
)
from repro.obs.prof import Prof, ProfileWindow  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    SpanTracer,
    instant_global,
    set_global_tracer,
)

__all__ = [
    "Observability", "Registry", "REGISTRY", "Counter", "Gauge",
    "Histogram", "CounterDict", "StatsView", "JsonlExporter",
    "merge_snapshots", "SpanTracer", "set_global_tracer",
    "instant_global", "Prof", "ProfileWindow",
]


class Observability:
    """The bundle an :class:`~repro.serving.engine.Engine` consumes.

    ``registry`` is ALWAYS live — it backs ``Engine.stats``, which
    predates this package.  ``tracer`` / ``exporter`` / ``window`` /
    ``prof`` are optional; each None is the documented noop path (see
    the package docstring).
    """

    def __init__(self, registry: Optional[Registry] = None,
                 tracer: Optional[SpanTracer] = None,
                 exporter: Optional[JsonlExporter] = None,
                 prof: Optional[Prof] = None,
                 window: Optional[ProfileWindow] = None):
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer
        self.exporter = exporter
        self.prof = prof if prof is not None else Prof(enabled=False)
        self.window = window

    @classmethod
    def off(cls) -> "Observability":
        """Default bundle: live registry, everything else noop."""
        return cls()

    @property
    def enabled(self) -> bool:
        """True when any optional surface is active."""
        return (self.tracer is not None or self.exporter is not None
                or self.window is not None or self.prof.enabled)

    def tick_hook(self):
        """Per-tick callback for the engine loop, or None when neither
        the exporter nor a profile window is configured — the engine
        stores the None and the tick loop pays one attribute test."""
        if self.exporter is None and self.window is None:
            return None

        def hook(tick_no: int) -> None:
            if self.window is not None:
                self.window.on_tick(tick_no)
            if self.exporter is not None:
                self.exporter.maybe_export(tick_no)

        return hook

    def close(self, tick: Optional[int] = None) -> None:
        """Flush everything: stop an in-flight profile window, close
        open trace spans, write a final metrics snapshot."""
        if self.window is not None:
            self.window.stop()
        if self.tracer is not None:
            self.tracer.close_all()
        if self.exporter is not None:
            self.exporter.close(tick)
