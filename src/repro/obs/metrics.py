"""Typed, labeled metric registry: Counter / Gauge / Histogram.

Why a registry instead of the grab-bag the engine grew (a flat
``Engine.stats`` dict, module-level dispatch-counter globals in
``kernels/ops.py``, timings that existed only inside ``benchmarks/``):
every consumer the ROADMAP names next — a multi-replica front door
reading per-replica health/load, a trace-driven load harness reporting
TTFT *and* time-per-output-token percentiles, training diagnostics for
the paper's init/depth sensitivity — needs the same three primitives
with one snapshot/merge/export story.  This module is that story, and it
is dependency-light on purpose (stdlib + numpy only, no jax): the
serving host loop, the kernels' trace-time dispatch counters and the
training launcher can all register into it without import cycles.

Primitives
----------
* :class:`Counter` — monotonic float/int accumulator (``inc``).  For
  back-compat with code that wrote raw dict entries it also accepts
  ``set`` (the ``Engine.stats`` view assigns through it); semantics are
  still "only ever grows" for everything the engine does.
* :class:`Gauge` — last-written value (``set``/``inc``).
* :class:`Histogram` — FIXED log-spaced bins, precomputed at
  construction: the hot path does one ``searchsorted`` into a static
  edge array and one integer bump — it never allocates, never rebins.
  Percentiles come from the bins (linear interpolation inside the
  containing bin), so a percentile is exact to within one bin width —
  the contract the serving bench asserts against its raw-list
  percentiles.

Labels: a metric family created with ``labels=("route",)`` is a factory;
``family.labels(route="fused")`` returns (and memoizes) the child
holding the actual value.  A family created without label names IS its
single child.

Registry-level verbs
--------------------
* ``snapshot()`` — plain deterministic dict (sorted keys, JSON-ready).
* ``merge_snapshots(a, b)`` — counters and histogram bins add, gauges
  take the right-hand value: the multi-replica aggregation rule.
* ``to_prometheus()`` — Prometheus text exposition (histograms as
  cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``).
* ``derived_gauge(name, fn)`` — computed at snapshot/read time, never
  stored: this is how ``acceptance_rate`` stays correct when a
  degradation to ``spec_off`` stops the drafted counter moving (the
  stale-last-value bug the flat dict had).

``REGISTRY`` is the process-global default: trace-time kernel dispatch
counters and autotune sweep events land there; engines own private
registries (one per replica) and exporters merge the two.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Callable, Dict, Iterable, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "CounterDict",
    "StatsView", "JsonlExporter", "REGISTRY", "merge_snapshots",
]


def _label_key(names: Tuple[str, ...], kv: Mapping[str, str]) -> Tuple:
    if set(kv) != set(names):
        raise ValueError(f"labels {sorted(kv)} != declared {sorted(names)}")
    return tuple(str(kv[n]) for n in names)


class _Family:
    """Shared labels machinery: a family with label names is a factory of
    children; without label names it is its own single child."""

    kind = "abstract"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._children: Dict[Tuple, "_Family"] = {}
        if not self.label_names:
            self._children[()] = self

    def labels(self, **kv) -> "_Family":
        key = _label_key(self.label_names, kv)
        child = self._children.get(key)
        if child is None:
            child = self._make_child()
            self._children[key] = child
        return child

    def _make_child(self):
        raise NotImplementedError

    def children(self):
        """(label_values_tuple, child) pairs, sorted for determinism."""
        return sorted(self._children.items())


class Counter(_Family):
    """Monotonic accumulator.  ``inc`` on the hot path; ``set`` exists
    only for the back-compat dict views (and stays monotonic in every
    engine code path, which only ever reads-modify-writes upward)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[str, ...] = ()):
        super().__init__(name, help, labels)
        self._value = 0.0

    def _make_child(self):
        return Counter(self.name)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def set(self, v: float) -> None:
        self._value = v

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Family):
    """Last-written value (degradation level, pool occupancy, loss)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[str, ...] = ()):
        super().__init__(name, help, labels)
        self._value = 0.0

    def _make_child(self):
        return Gauge(self.name)

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    @property
    def value(self) -> float:
        return self._value


#: default histogram range: 10 microseconds .. 1000 seconds, 8 bins per
#: decade — wide enough for TTFT, TPOT and tick latencies at once, and
#: the relative bin width (r - 1 ~ 33%) bounds percentile error.
DEFAULT_LO = 1e-5
DEFAULT_HI = 1e3
DEFAULT_BINS_PER_DECADE = 8


class Histogram(_Family):
    """Fixed log-spaced-bin histogram.

    Edges are computed ONCE at construction (``lo * r**i`` up to ``hi``,
    ``r = 10**(1/bins_per_decade)``); ``observe`` is a searchsorted into
    that static array plus an integer bump — no allocation, no rebin, so
    it is safe on the serving tick path.  Values below ``lo`` land in the
    underflow bin, at or above ``hi`` in the overflow bin.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[str, ...] = (), lo: float = DEFAULT_LO,
                 hi: float = DEFAULT_HI,
                 bins_per_decade: int = DEFAULT_BINS_PER_DECADE):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        self.lo, self.hi = float(lo), float(hi)
        self.bins_per_decade = int(bins_per_decade)
        n = int(math.ceil(math.log10(hi / lo) * bins_per_decade))
        # interior edges lo .. hi inclusive; counts has underflow (index
        # 0) and overflow (index -1) buckets around the n interior bins
        self.edges = np.asarray(
            [lo * 10.0 ** (i / bins_per_decade) for i in range(n)] + [hi],
            np.float64)
        super().__init__(name, help, labels)
        self.counts = np.zeros(len(self.edges) + 1, np.int64)
        self._sum = 0.0

    def _make_child(self):
        return Histogram(self.name, lo=self.lo, hi=self.hi,
                         bins_per_decade=self.bins_per_decade)

    def observe(self, v: float) -> None:
        self.counts[int(np.searchsorted(self.edges, v, side="right"))] += 1
        self._sum += v

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> Optional[float]:
        """q-th percentile (0..100) from the bins, or None when empty.

        Linear interpolation inside the containing bin; the underflow
        bin reports ``lo`` and the overflow bin ``hi`` (the histogram
        cannot resolve beyond its range).  Error bound: one bin width at
        the reported value.
        """
        total = self.count
        if total == 0:
            return None
        rank = q / 100.0 * total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, max(rank, 1e-12), side="left"))
        if i == 0:
            return self.lo
        if i >= len(self.edges):
            return self.hi
        lo_edge = float(self.edges[i - 1])
        hi_edge = float(self.edges[i]) if i < len(self.edges) else self.hi
        prev = float(cum[i - 1])
        inside = float(self.counts[i])
        frac = (rank - prev) / inside if inside > 0 else 0.0
        return lo_edge + (hi_edge - lo_edge) * min(max(frac, 0.0), 1.0)

    def reset(self) -> None:
        """Zero the bins.  Not a Prometheus verb — this exists so benches
        can exclude their compile-warmup observations from the reported
        percentiles (the same reason they delta the stats counters)."""
        self.counts[:] = 0
        self._sum = 0.0

    def bin_width(self, v: float) -> float:
        """Width of the bin containing ``v`` — the percentile error
        bound the serving bench asserts against."""
        i = int(np.searchsorted(self.edges, v, side="right"))
        if i == 0:
            return float(self.edges[0])
        if i >= len(self.edges):
            return float("inf")
        return float(self.edges[i] - self.edges[i - 1])


class Registry:
    """Named metric families + derived gauges, with snapshot/merge/export.

    Registration is get-or-create and type-checked: asking for the same
    name with a different kind (or different label names) is an error,
    so two subsystems can safely share one registry.
    """

    def __init__(self):
        self._metrics: Dict[str, _Family] = {}
        self._derived: Dict[str, Callable[[], float]] = {}
        self._lock = threading.Lock()

    def _get_or_make(self, cls, name: str, help: str, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or m.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{m.kind}{m.label_names}")
                return m
            m = cls(name, help, tuple(labels), **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Tuple[str, ...] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Tuple[str, ...] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Tuple[str, ...] = (), lo: float = DEFAULT_LO,
                  hi: float = DEFAULT_HI,
                  bins_per_decade: int = DEFAULT_BINS_PER_DECADE
                  ) -> Histogram:
        return self._get_or_make(Histogram, name, help, labels, lo=lo,
                                 hi=hi, bins_per_decade=bins_per_decade)

    def derived_gauge(self, name: str, fn: Callable[[], float],
                      help: str = "") -> Callable[[], float]:
        """A gauge COMPUTED at read/snapshot time — never stored, so it
        can never go stale (the ``acceptance_rate`` fix)."""
        if name in self._metrics:
            raise ValueError(f"metric {name!r} already registered")
        self._derived[name] = fn
        return fn

    def get(self, name: str) -> Optional[_Family]:
        return self._metrics.get(name)

    # -- snapshot / merge / exposition -----------------------------------

    def snapshot(self) -> dict:
        """Deterministic plain-dict state (sorted names, JSON-ready).

        Shape::

            {"counters":   {name: {label_str: value}},
             "gauges":     {name: {label_str: value}},
             "histograms": {name: {label_str: {"edges": [...],
                                               "counts": [...],
                                               "sum": float}}}}

        ``label_str`` is ``"a=x,b=y"`` (sorted by label name) or ``""``
        for unlabeled metrics.  Derived gauges are evaluated here.
        """
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            fam = self._metrics[name]
            sec = {"counter": "counters", "gauge": "gauges",
                   "histogram": "histograms"}[fam.kind]
            entry = {}
            for vals, child in fam.children():
                label_str = ",".join(
                    f"{n}={v}" for n, v in zip(fam.label_names, vals))
                if fam.kind == "histogram":
                    entry[label_str] = {
                        "edges": [float(e) for e in child.edges],
                        "counts": [int(c) for c in child.counts],
                        "sum": float(child.sum),
                    }
                else:
                    entry[label_str] = float(child.value)
            out[sec][name] = entry
        for name in sorted(self._derived):
            out["gauges"].setdefault(name, {})[""] = float(
                self._derived[name]())
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition of the current state."""
        lines = []
        snap = self.snapshot()
        helps = {n: m.help for n, m in self._metrics.items()}
        for sec, kind in (("counters", "counter"), ("gauges", "gauge")):
            for name in snap[sec]:
                if helps.get(name):
                    lines.append(f"# HELP {name} {helps[name]}")
                lines.append(f"# TYPE {name} {kind}")
                for label_str, v in snap[sec][name].items():
                    lbl = "{%s}" % _prom_labels(label_str) if label_str \
                        else ""
                    lines.append(f"{name}{lbl} {_prom_num(v)}")
        for name, entry in snap["histograms"].items():
            if helps.get(name):
                lines.append(f"# HELP {name} {helps[name]}")
            lines.append(f"# TYPE {name} histogram")
            for label_str, h in entry.items():
                base = _prom_labels(label_str)
                cum = 0
                for edge, c in zip(h["edges"], h["counts"]):
                    cum += c
                    le = f'le="{_prom_num(edge)}"'
                    lbl = f"{base},{le}" if base else le
                    lines.append(f"{name}_bucket{{{lbl}}} {cum}")
                cum += h["counts"][-1]
                le = 'le="+Inf"'
                lbl = f"{base},{le}" if base else le
                lines.append(f"{name}_bucket{{{lbl}}} {cum}")
                sfx = "{%s}" % base if base else ""
                lines.append(f"{name}_sum{sfx} {_prom_num(h['sum'])}")
                lines.append(f"{name}_count{sfx} {cum}")
        return "\n".join(lines) + "\n"


def _prom_labels(label_str: str) -> str:
    if not label_str:
        return ""
    return ",".join(f'{k}="{v}"'
                    for k, v in (p.split("=", 1)
                                 for p in label_str.split(",")))


def _prom_num(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def merge_snapshots(a: dict, b: dict) -> dict:
    """Combine two :meth:`Registry.snapshot` dicts (multi-replica rule):
    counters and histogram bin counts/sums ADD; gauges take ``b``'s value
    (last writer wins — gauges are point-in-time observations).
    Histograms being merged must share their edge grid."""
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    for sec in ("counters", "gauges"):
        for name in sorted(set(a[sec]) | set(b[sec])):
            ea, eb = a[sec].get(name, {}), b[sec].get(name, {})
            entry = {}
            for label in sorted(set(ea) | set(eb)):
                if sec == "counters":
                    entry[label] = ea.get(label, 0.0) + eb.get(label, 0.0)
                else:
                    entry[label] = eb[label] if label in eb else ea[label]
            out[sec][name] = entry
    for name in sorted(set(a["histograms"]) | set(b["histograms"])):
        ea = a["histograms"].get(name, {})
        eb = b["histograms"].get(name, {})
        entry = {}
        for label in sorted(set(ea) | set(eb)):
            if label in ea and label in eb:
                ha, hb = ea[label], eb[label]
                if ha["edges"] != hb["edges"]:
                    raise ValueError(
                        f"histogram {name!r} edge grids differ")
                entry[label] = {
                    "edges": list(ha["edges"]),
                    "counts": [x + y for x, y in zip(ha["counts"],
                                                     hb["counts"])],
                    "sum": ha["sum"] + hb["sum"],
                }
            else:
                src = ea.get(label) or eb[label]
                entry[label] = {"edges": list(src["edges"]),
                                "counts": list(src["counts"]),
                                "sum": src["sum"]}
        out["histograms"][name] = entry
    return out


class CounterDict:
    """Dict-shim over a labeled :class:`Counter` family.

    The kernel dispatch counters (``ops.CASCADE_BWD_DISPATCHES``,
    ``ops.PAGED_ATTN_DISPATCHES``) predate the registry as module-level
    dicts; tests and benches read them with ``dict(...)`` copies, key
    iteration and ``[key]`` lookups, and ops.py bumps them with
    ``[key] += 1``.  This shim keeps that exact surface while the values
    live in registry counters — one implementation, two spellings.
    """

    def __init__(self, family: Counter, keys: Iterable[str]):
        if len(family.label_names) != 1:
            raise ValueError("CounterDict needs a single-label family")
        self._family = family
        self._label = family.label_names[0]
        self._keys = tuple(keys)
        for k in self._keys:          # register children eagerly so
            self._child(k)            # iteration order is stable

    def _child(self, key: str) -> Counter:
        if key not in self._keys:
            raise KeyError(key)
        return self._family.labels(**{self._label: key})

    def __getitem__(self, key: str) -> int:
        return int(self._child(key).value)

    def __setitem__(self, key: str, value) -> None:
        self._child(key).set(value)

    def __iter__(self):
        return iter(self._keys)

    def __len__(self):
        return len(self._keys)

    def __contains__(self, key):
        return key in self._keys

    def keys(self):
        return self._keys

    def items(self):
        return [(k, self[k]) for k in self._keys]

    def values(self):
        return [self[k] for k in self._keys]

    def __repr__(self):
        return repr(dict(self.items()))

    def __eq__(self, other):
        return dict(self.items()) == other


class StatsView:
    """Back-compat dict facade over registry metrics.

    ``Engine.stats`` predates the registry as a flat mutable dict; every
    engine code path reads/writes it as ``stats[key] += 1`` and callers
    copy it with ``dict(eng.stats)``.  This view keeps that exact
    surface: each key is *bound* to a getter (metric read, or a derived
    computation) and optionally a setter (metric write).  Keys bound
    without a setter — derived gauges like ``acceptance_rate`` — are
    read-only; assigning to them raises, because a stored value is
    exactly the staleness bug the derived form fixes.
    """

    def __init__(self):
        self._getters: Dict[str, Callable[[], float]] = {}
        self._setters: Dict[str, Callable[[float], None]] = {}

    def bind(self, key: str, getter: Callable[[], float],
             setter: Optional[Callable[[float], None]] = None) -> None:
        self._getters[key] = getter
        if setter is not None:
            self._setters[key] = setter

    def __getitem__(self, key: str):
        return self._getters[key]()

    def __setitem__(self, key: str, value) -> None:
        setter = self._setters.get(key)
        if setter is None:
            if key not in self._getters:
                raise KeyError(key)
            raise TypeError(
                f"stats[{key!r}] is derived at read time and cannot be "
                f"assigned")
        setter(value)

    def __contains__(self, key) -> bool:
        return key in self._getters

    def __iter__(self):
        return iter(self._getters)

    def __len__(self) -> int:
        return len(self._getters)

    def keys(self):
        return self._getters.keys()

    def values(self):
        return [self[k] for k in self._getters]

    def items(self):
        return [(k, self[k]) for k in self._getters]

    def get(self, key, default=None):
        return self[key] if key in self._getters else default

    def __eq__(self, other):
        return dict(self.items()) == other

    def __repr__(self):
        return f"StatsView({dict(self.items())!r})"


class JsonlExporter:
    """Periodic JSON-lines snapshot export.

    One line per export: ``{"t": <clock>, "tick": <n>, "metrics":
    <snapshot>}``.  ``every`` is in ticks (the engine calls
    :meth:`maybe_export` once per tick); ``extra_snapshots`` is a list of
    callables merged in (the serve launcher passes the process-global
    ``REGISTRY.snapshot`` so kernel dispatch counters ride along with the
    engine's registry).  The file handle is line-buffered append; call
    :meth:`close` (or rely on the final export) when done.
    """

    def __init__(self, path: str, registry: Registry, every: int = 50,
                 clock: Optional[Callable[[], float]] = None,
                 extra_snapshots: Tuple[Callable[[], dict], ...] = ()):
        self.path = path
        self.registry = registry
        self.every = max(int(every), 1)
        self.clock = clock
        self.extra_snapshots = tuple(extra_snapshots)
        self.exports = 0
        self._fh = open(path, "a", buffering=1)

    def _snapshot(self) -> dict:
        snap = self.registry.snapshot()
        for fn in self.extra_snapshots:
            snap = merge_snapshots(snap, fn())
        return snap

    def export(self, tick: Optional[int] = None) -> None:
        rec = {"tick": tick, "metrics": self._snapshot()}
        if self.clock is not None:
            rec["t"] = self.clock()
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self.exports += 1

    def maybe_export(self, tick: int) -> None:
        if tick % self.every == 0:
            self.export(tick)

    def close(self, tick: Optional[int] = None) -> None:
        if self._fh.closed:
            return
        self.export(tick)
        self._fh.close()


#: process-global default registry: trace-time kernel dispatch counters,
#: autotune sweep events and straggler flags land here; per-engine
#: registries are separate and merged at export time.
REGISTRY = Registry()
