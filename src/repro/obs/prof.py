"""``jax.profiler`` hooks: named-scope annotations + on-demand windows.

Two cheap bridges between the serving/training host loops and JAX's own
profiler, both default-off:

* :class:`Prof` — ``prof.annotate("decode")`` wraps a host-side dispatch
  in a ``jax.profiler.TraceAnnotation`` so prefill / decode / verify /
  draft show up as named rows in a captured trace.  Disabled (the
  default), ``annotate`` returns one shared no-op context manager —
  no allocation, no jax call — which is the entirety of the engine's
  profiling overhead when off.

* :class:`ProfileWindow` — parses the launcher's ``--profile-ticks A:B``
  and drives ``jax.profiler.start_trace`` / ``stop_trace`` at exactly
  those engine tick boundaries (start at the beginning of tick A, stop
  after tick B), so a long overload run can capture a narrow window
  around the interesting ticks instead of profiling the whole run.  The
  capture lands in ``logdir`` in TensorBoard/XPlane format; ``stop()``
  is idempotent and also runs from ``Observability.close`` so a run that
  ends inside the window still flushes it.

(Trace-time ``jax.named_scope`` annotations inside the kernels are free
and always on — they only label the jaxpr/HLO; see ``kernels/ops.py``.)
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

__all__ = ["Prof", "ProfileWindow", "parse_tick_window"]

_NULL = contextlib.nullcontext()


class Prof:
    """Named-scope annotation source; one shared no-op when disabled."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled

    def annotate(self, name: str):
        if not self.enabled:
            return _NULL
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)


def parse_tick_window(spec: str) -> Tuple[int, int]:
    """``"A:B"`` -> (A, B), inclusive tick bounds, validated."""
    try:
        a_s, b_s = spec.split(":")
        a, b = int(a_s), int(b_s)
    except ValueError:
        raise ValueError(
            f"--profile-ticks wants 'A:B' (tick bounds), got {spec!r}")
    if a < 0 or b < a:
        raise ValueError(f"--profile-ticks needs 0 <= A <= B, got {spec!r}")
    return a, b


class ProfileWindow:
    """Start/stop a ``jax.profiler`` trace across ticks [A, B]."""

    def __init__(self, spec: str, logdir: str):
        self.start_tick, self.stop_tick = parse_tick_window(spec)
        self.logdir = logdir
        self.active = False
        self.done = False

    def on_tick(self, tick_no: int) -> None:
        """Called once per engine tick, BEFORE the tick body runs."""
        if (not self.done and not self.active
                and tick_no >= self.start_tick):
            import jax.profiler
            jax.profiler.start_trace(self.logdir)
            self.active = True
        elif self.active and tick_no > self.stop_tick:
            self.stop()

    def stop(self) -> None:
        if self.active:
            import jax.profiler
            jax.profiler.stop_trace()
            self.active = False
        self.done = True
