"""Per-request span tracing over an injectable monotonic clock.

The tracer records the serving engine's request lifecycle as a flat
chain of **phase spans** per request —

    queued -> prefill -> decode -> {preempt -> backoff -> queued ->
    prefill -> decode}* -> terminal(finish_reason)

— plus **instant events**: per-request marks (``preempt``, exactly one
``terminal:<finish_reason>``) and engine-track tick events (degradation-
ladder transitions, deadline preemptions, FaultPlan injections,
allocator audits, straggler flags).  A phase span opens when the request
enters the phase and closes when the next phase (or the terminal event)
begins, so per-request spans are contiguous and non-overlapping by
construction — the well-formedness the chaos trace test asserts.

Clock: injectable and monotonic-by-contract.  The engine adopts its own
clock into an unset tracer (``clock=None``), so the virtual ``FakeClock``
the resilience tests drive produces deterministic traces, and a replay of
the same seeded chaos run yields byte-identical exports.

Export is Chrome/Perfetto trace-event JSON (the ``traceEvents`` array
format): phase spans become ``"X"`` complete events with microsecond
``ts``/``dur`` relative to the first event, instants become ``"i"``
events, and ``"M"`` metadata events name one thread track per request
(``req <rid>``) plus one per engine-side track — open
``chrome://tracing`` / https://ui.perfetto.dev and load the file.

A module-level **global tracer hook** (:func:`set_global_tracer` /
:func:`instant_global`) lets deep layers that must not depend on the
engine — the block allocator's ``audit()``, the training straggler
monitor, autotune sweep completions — emit events when a tracer is
installed and cost one ``is None`` check when not.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["Span", "Instant", "SpanTracer", "set_global_tracer",
           "instant_global"]


@dataclasses.dataclass
class Span:
    """One closed lifecycle phase: [t0, t1) on a request's track."""
    track: str
    name: str
    t0: float
    t1: float
    args: Dict[str, Any]


@dataclasses.dataclass
class Instant:
    """A point event on a request or engine track."""
    track: str
    name: str
    t: float
    args: Dict[str, Any]


class SpanTracer:
    """Collects spans/instants; exports Chrome trace-event JSON.

    Not thread-safe (the engine tick loop is single-threaded); event
    order is the emission order, so identical runs yield identical
    traces.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        #: left None, the first engine this tracer is attached to adopts
        #: its own clock (virtual or wall) — see Engine.__init__
        self.clock = clock
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        # rid -> (phase_name, t0, args) for the currently-open phase
        self._open: Dict[int, Tuple[str, float, Dict[str, Any]]] = {}
        self._order: List[str] = []     # track names in first-seen order

    # -- emission --------------------------------------------------------

    def _now(self) -> float:
        return (self.clock or time.monotonic)()

    def _track(self, name: str) -> str:
        if name not in self._order:
            self._order.append(name)
        return name

    def req_phase(self, rid: int, phase: str, **args) -> None:
        """Enter ``phase`` on request ``rid``'s track, closing the
        previously open phase at the same timestamp (contiguous spans)."""
        now = self._now()
        self._close(rid, now)
        self._open[rid] = (phase, now, args)
        self._track(f"req {rid}")

    def req_instant(self, rid: int, name: str, **args) -> None:
        self.instants.append(Instant(self._track(f"req {rid}"), name,
                                     self._now(), args))

    def req_terminal(self, rid: int, finish_reason: str, **args) -> None:
        """Close the request's open phase and emit its single terminal
        instant ``terminal:<finish_reason>``."""
        now = self._now()
        self._close(rid, now)
        self.instants.append(Instant(
            self._track(f"req {rid}"), f"terminal:{finish_reason}", now,
            dict(args, finish_reason=finish_reason)))

    def instant(self, track: str, name: str, **args) -> None:
        """Engine-side point event (ladder move, fault injection, ...)."""
        self.instants.append(Instant(self._track(track), name, self._now(),
                                     args))

    def _close(self, rid: int, now: float) -> None:
        open_ = self._open.pop(rid, None)
        if open_ is not None:
            phase, t0, args = open_
            self.spans.append(Span(f"req {rid}", phase, t0, now, args))

    def close_all(self) -> None:
        """Close any still-open phases at the current clock (requests
        left non-terminal when the run stopped)."""
        now = self._now()
        for rid in list(self._open):
            self._close(rid, now)

    # -- queries (test/debug surface) ------------------------------------

    def spans_for(self, rid: int) -> List[Span]:
        track = f"req {rid}"
        return [s for s in self.spans if s.track == track]

    def terminals_for(self, rid: int) -> List[Instant]:
        track = f"req {rid}"
        return [i for i in self.instants
                if i.track == track and i.name.startswith("terminal:")]

    # -- Chrome trace export ---------------------------------------------

    def chrome_trace(self) -> dict:
        """The trace as a Chrome trace-event JSON object.

        ``ts`` is microseconds relative to the earliest event, so virtual
        clocks starting at 0.0 and wall clocks both render sensibly.
        Still-open phases are closed at the current clock first.
        """
        self.close_all()
        events = []
        times = ([s.t0 for s in self.spans]
                 + [i.t for i in self.instants])
        base = min(times) if times else 0.0
        tids = {name: i + 1 for i, name in enumerate(self._order)}
        for name, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": name}})
        us = 1e6
        for s in self.spans:
            events.append({
                "ph": "X", "name": s.name, "pid": 1,
                "tid": tids[s.track],
                "ts": (s.t0 - base) * us,
                "dur": max((s.t1 - s.t0) * us, 0.0),
                "args": s.args,
            })
        for i in self.instants:
            events.append({
                "ph": "i", "s": "t", "name": i.name, "pid": 1,
                "tid": tids[i.track],
                "ts": (i.t - base) * us,
                "args": i.args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)


#: process-global tracer hook for layers that must not import the engine
#: (allocator audits, straggler flags, autotune sweeps).  None (default)
#: means every instant_global call is one comparison and a return.
_GLOBAL: Optional[SpanTracer] = None


def set_global_tracer(tracer: Optional[SpanTracer]) -> None:
    global _GLOBAL
    _GLOBAL = tracer


def instant_global(track: str, name: str, **args) -> None:
    if _GLOBAL is not None:
        _GLOBAL.instant(track, name, **args)
