"""Optimizers (no external deps): AdamW and SGD+momentum with param groups.

The paper's CaffeNet recipe needs per-group treatment: learning-rate
multipliers of x24 on the **A** diagonals and x12 on **D**, weight decay
excluded from the SELL diagonals, and step-decay (x0.1 every 100k).  That is
expressed here as path-regex param groups, the same mechanism the LM zoo
uses to exclude norms/biases from decay.
"""

from repro.optim.optimizers import (  # noqa: F401
    OptimizerConfig,
    adamw,
    sgd_momentum,
    make_optimizer,
    tree_paths,
    global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_schedule,
    step_decay_schedule,
)
