"""Functional optimizers with path-regex param groups.

API (optax-like but dependency-free)::

    opt = make_optimizer(OptimizerConfig(...), schedule)
    opt_state = opt.init(params)
    updates, opt_state = opt.update(grads, opt_state, params, step)
    params = tree_add(params, updates)

Param groups are (regex, overrides) pairs matched against "a/b/c" tree
paths; the first match wins.  Supported overrides: ``lr_mult``,
``weight_decay``.  The paper's recipe is then just::

    groups = [(r".*sell/a$", {"lr_mult": 24.0, "weight_decay": 0.0}),
              (r".*sell/d$", {"lr_mult": 12.0, "weight_decay": 0.0})]
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Tree utilities.
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_paths(tree):
    """Same-structure tree of 'a/b/c' path strings."""
    return jax.tree_util.tree_map_with_path(lambda p, _: _path_str(p), tree)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y.astype(x.dtype), a, b)


# ---------------------------------------------------------------------------
# Config.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"              # adamw | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    momentum: float = 0.9            # sgd
    weight_decay: float = 0.1
    grad_clip: float = 1.0           # global-norm clip; 0 = off
    # (regex, {"lr_mult": float, "weight_decay": float}) — first match wins
    groups: Tuple[Tuple[str, dict], ...] = ()
    # keep first/second moments in bfloat16 (distributed-memory trick)
    compact_state: bool = False


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def _group_maps(cfg: OptimizerConfig, params):
    paths = tree_paths(params)
    compiled = [(re.compile(rx), ov) for rx, ov in cfg.groups]

    def resolve(path, key, default):
        for rx, ov in compiled:
            if rx.search(path):
                return ov.get(key, default)
        return default

    lr_mults = jax.tree.map(lambda p: resolve(p, "lr_mult", 1.0), paths)
    wds = jax.tree.map(lambda p: resolve(p, "weight_decay", cfg.weight_decay),
                       paths)
    return lr_mults, wds


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------

def adamw(cfg: OptimizerConfig, schedule: Callable) -> Optimizer:
    state_dtype = jnp.bfloat16 if cfg.compact_state else jnp.float32

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        lr_mults, wds = _group_maps(cfg, params)
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if cfg.grad_clip > 0:
            gn = global_norm(gf)
            scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)

        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        new_m = jax.tree.map(
            lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                          + (1 - cfg.b1) * g).astype(state_dtype),
            state["m"], gf)
        new_v = jax.tree.map(
            lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                          + (1 - cfg.b2) * jnp.square(g)).astype(state_dtype),
            state["v"], gf)

        def upd(m, v, p, mult, wd):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            u = mhat / (jnp.sqrt(vhat) + cfg.eps)
            u = u + wd * p.astype(jnp.float32)
            return (-lr * mult * u).astype(p.dtype)

        updates = jax.tree.map(upd, new_m, new_v, params, lr_mults, wds)
        return updates, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# SGD + momentum (the paper's CaffeNet optimizer).
# ---------------------------------------------------------------------------

def sgd_momentum(cfg: OptimizerConfig, schedule: Callable) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                    params)}

    def update(grads, state, params, step):
        lr = schedule(step)
        lr_mults, wds = _group_maps(cfg, params)
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if cfg.grad_clip > 0:
            gn = global_norm(gf)
            scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)

        # caffe-style: mom = mu*mom + lr_eff*(g + wd*p); p -= mom
        def step_fn(mom, g, p, mult, wd):
            g = g + wd * p.astype(jnp.float32)
            return cfg.momentum * mom + lr * mult * g

        new_mom = jax.tree.map(step_fn, state["mom"], gf, params,
                               lr_mults, wds)
        updates = jax.tree.map(lambda m, p: (-m).astype(p.dtype),
                               new_mom, params)
        return updates, {"mom": new_mom}

    return Optimizer(init=init, update=update)


def make_optimizer(cfg: OptimizerConfig, schedule: Callable) -> Optimizer:
    if cfg.kind == "adamw":
        return adamw(cfg, schedule)
    if cfg.kind == "sgd":
        return sgd_momentum(cfg, schedule)
    raise ValueError(cfg.kind)
