"""Learning-rate schedules (step -> lr scalars, jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def step_decay_schedule(lr: float, decay: float = 0.1, every: int = 100_000):
    """The paper's CaffeNet schedule: lr * decay^(floor(step/every))."""
    def fn(step):
        k = jnp.floor(step.astype(jnp.float32) / every)
        return lr * (decay ** k)
    return fn


def cosine_schedule(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return fn
