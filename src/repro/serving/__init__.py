"""Serving engine: batched prefill + continuous batching over the model zoo.

Why this package exists: ACDC's pitch is cheap inference — O(N) parameters,
O(N log N) operations per structured projection — and the serving layer is
where that cost advantage is actually cashed in.  This package turns the
model zoo's decode machinery (KV caches in ``repro/models/attention.py``,
SSM/conv state in ``repro/models/mamba2.py``) into an engine.

Request lifecycle
-----------------
A :class:`Request` (``request.py``) carries a ragged-length prompt, its
stop conditions (``eos_id``, ``max_new_tokens``), and its scheduling
inputs (``deadline_s``, ``priority``, ``max_preemptions``).
``Engine.submit`` validates it and hands it to the deadline-aware
:class:`Scheduler` (``scheduler.py``) as QUEUED.  When a batch slot frees
up it becomes ACTIVE: one lowered **prefill** program
(``make_prefill_step``) runs the whole context, scatters the resulting
KV / SSM state into the slot's cache row, and samples the next token —
the time-to-first-token mark on first admission.  Each subsequent engine
tick advances it one token; a terminal condition flips it to FINISHED
and releases the slot.  ``finish_reason`` is one of the closed
:class:`FinishReason` set:

* ``"eos"`` — generated the request's ``eos_id``;
* ``"length"`` — generated ``max_new_tokens`` tokens;
* ``"cache_full"`` — hit the per-slot ``max_len`` cache ceiling, or was
  terminally evicted while its context was too long to re-prefill;
* ``"timeout"`` — passed ``t_submit + deadline_s`` (queued or active);
* ``"preempted_limit"`` — needed another preemption after exhausting its
  ``max_preemptions`` requeue budget;
* ``"rejected"`` — shed at submission by the degradation ladder's
  bounded queue (overload; lowest priority goes first).

Preempt -> requeue -> re-prefill
--------------------------------
Preemption is the engine's universal recovery move: the victim slot is
released (pages returned to the pool), the request moves ACTIVE ->
QUEUED with its ``generated`` tokens kept, and after an exponential
tick backoff (``2^(n_preemptions - 1)`` ticks, capped at 64) it
re-enters the queue with its original arrival ``seq`` — seniority and
deadline urgency are unchanged.  Readmission re-prefills the whole
context ``prompt + generated`` and samples the next token from the
last-position logits; because prefill and decode agree
position-for-position (pinned by tests/test_decode_consistency.py), a
greedy stream continues **bit-identically** to an undisturbed run —
recompute makes preemption transparent, trading only latency.  The same
state machine serves four callers: the all-stalled deadlock breaker
(pool exhausted), deadline preemption (a queued request about to miss
its deadline evicts the active request with the most slack), corrupt-
output healing (non-finite logits -> out-of-range sampled ids ->
requeue instead of committing garbage), and the public
``Engine.preempt(slot)`` hook (a multi-replica front door's
drain-and-redistribute building block).  A request that cannot requeue
(budget spent, or ``prompt + generated`` no longer fits
``max_prompt_len``) is finished terminally instead
(``preempted_limit`` / ``cache_full``).

Deadline-aware scheduling
-------------------------
Admission order is earliest-deadline-first: queued requests sort by
absolute deadline (no deadline sorts last), then priority, then arrival
— exactly FIFO when no deadlines or priorities are set.  Each tick
sweeps queued requests already past their deadline to ``timeout``
without burning a prefill, and evicts active ones on expiry.  A
capacity-blocked queue head is aged (``scheduler.py``): after
``age_limit`` skipped passes the scheduler admits nobody else, so freed
capacity accrues until the head fits — bounding head-of-line starvation
that the bounded lookahead ``window`` alone could sustain forever.

Graceful-degradation ladder
---------------------------
A tick-latency watchdog (:class:`repro.dist.elastic.StragglerMonitor`)
plus pool-pressure (all slots stalled on a dry pool) and queue-depth
signals drive a reversible ladder: ``full -> spec_half -> spec_off ->
shed`` (speculation rungs exist only when ``spec_k`` allows).  Each
step down shrinks speculative depth, then disables speculation, then
bounds the admission queue at ``queue_bound`` and sheds the lowest-
priority request (``finish_reason="rejected"``).  Ordering guarantees:
rungs are strictly ordered cheapest-first; transitions are counted in
``stats["degrade_down"/"degrade_up"/"degrade_level"]``; and **no
transition ever alters a greedy token stream** — speculation is exact
at any depth (including 0) and shedding only drops whole requests at
submission, never tokens from streaming ones.  After
``degrade_up_after`` consecutive calm ticks the engine steps back up;
the watchdog baseline resets on every transition because the per-tick
cost legitimately changed.

Fault injection
---------------
``Engine(..., fault=FaultPlan(seed=...))`` (``faults.py``) threads a
deterministic seed-driven chaos schedule behind a no-op default into
the allocator (capacity checks / page mapping report a dry pool) and
the tick loop (non-finite logits on chosen ticks, simulated slow ticks
for the watchdog, spurious slot stalls).  Each fault surface draws from
its own seeded stream, so plans replay exactly;
``BlockAllocator.audit()`` must come back clean after any plan
(tests/test_serving_faults.py replays seeded chaos and asserts every
request reaches a terminal state with unpreempted streams
bit-identical).

Slot model
----------
The :class:`Engine` (``engine.py``) owns a fixed-shape cache with
``n_slots`` batch rows (max_len positions each).  Prefill writes a slot's
entire row — positions at or beyond the prompt length are zeroed, because
the decode path scatters additively — so slots are reused without a reset
pass.  Free slots ride through decode parked at ``position = max_len``,
where the one-hot scatter writes nothing.  Per-request compute is
batch-row-independent, so outputs are identical to running each request
alone (pinned by tests/test_serving_engine.py).

Paged block KV cache
--------------------
``Engine(..., paged=True, block_size=B, n_blocks=N)`` replaces the dense
per-slot ``max_len`` slabs with ONE global pool of ``N`` pages of ``B``
token positions each (``blocks.py``), so short requests stop paying a long
request's worst-case memory.  The device layout (shared with
``repro.models.attention``):

* page pool ``(n_layers, N + 1, B, Hkv, Dh)`` per K and V — physical page
  ``N`` is the write sink for parked/stalled rows, never read back;
* block table: static ``(n_slots, ceil(max_len / B))`` int32, entry
  ``[slot, i]`` = physical page for token positions ``[i*B, (i+1)*B)``,
  ``-1`` when unmapped.  The host-side :class:`BlockAllocator` owns it and
  the engine ships it to the device each tick.

The table carries two invariants the attention consumers rely on:

* **frontier** — for any slot the engine decodes at ``position = p <
  virtual`` (virtual = ``ceil(max_len / B) * B``), every entry covering
  ``[0, p]`` is mapped: ``_ensure_blocks`` maps the tick's whole write
  window up front and *parks* (stalls) any slot it cannot serve at
  ``position = virtual``.  Unmapped entries therefore only ever sit
  ABOVE a live slot's frontier.
* **masking** — readers must derive their key mask from ``position``
  alone, never from table occupancy: pages are recycled across requests
  (evict -> admit remaps them to other slots mid-stream), so a freed
  page holds stale K/V that only the causal/frontier mask keeps out of
  attention (pinned by tests/test_paged_attention.py).

Two interchangeable attention consumers honour that contract
(``ops.paged_attn_route`` picks per trace, counting decisions in
``PAGED_ATTN_DISPATCHES``): the block-table *gather* in
``models/attention.py`` — materialises the ``(n_slots, virtual, Hkv,
Dh)`` view, routing unmapped entries through page 0 (masked anyway) —
and the fused Pallas kernel in ``kernels/paged_attn.py``, which streams
only the mapped in-frontier pages (O(len) bytes per slot instead of the
gather's O(max_len)) and is the TPU default whenever an autotuned block
fits VMEM; the gather stays as the over-budget/interpret fallback.
Greedy streams are bit-identical either way.

Admission contract: the queue head is admitted only when
``ceil((prompt_len + 1) / B)`` pages are free — prompt plus room for the
first decode token — so admission never strands a request with nowhere to
write.  Decode growth maps pages lazily each tick; a slot the pool cannot
serve *stalls* (parks for the tick, produces nothing, resumes when an
eviction frees pages), and an all-stalled deadlock is broken by
preempting-with-requeue the lowest-priority stalled request holding the
most pages (see the state machine above).  Because slots are compute-
isolated, greedy output streams under paging are identical to the dense
cache (pinned by tests/test_serving_paged.py); only scheduling/latency
can shift when the pool is tight.  Families: transformer and encdec page
their (self-attention) KV, zamba2 pages only the shared-attention KV
(Mamba SSM/conv state is O(1) per slot and stays dense), mamba2 has
nothing to page by construction.

Admission under paging uses a bounded head-of-line lookahead (scheduler
``window``, default 4): when the queue head's prompt does not fit the
free pool, the first of the next ``window`` queued requests that does is
admitted instead — the head stays at the front and is retried every
pass, so one large request cannot starve a stream of small ones.

Tick loop
---------
``tick()`` = admit (0+ prefill dispatches, one per admission) + one fused
decode step over all ``n_slots`` rows + evict.  All shapes are static, so
the engine compiles exactly two programs — one prefill, one decode — no
matter how traffic arrives (paged mode fuses the admission page scatter
into the prefill program, keeping the count at two).  ``run(requests)``
ticks until drained, raising once ``max_ticks`` ticks have run without
draining.

Speculative tick (``spec_k > 0``)
---------------------------------
The decode step is replaced by **draft -> verify -> accept/rollback**
(:mod:`repro.spec`):

1. *draft* — one fused program proposes ``k`` tokens per slot from a
   cheap draft source (default: the target's own ACDC cascades truncated
   to ``draft_depth`` layers, the paper's depth result as a free draft);
2. *verify* — ONE target program (``make_verify_step``) appends all
   ``k + 1`` tokens per slot (pending + drafts) to the cache as a
   position-masked mini-prefill, scores every position, accepts the
   longest draft prefix the target agrees with, and commits;
3. *accept/rollback* — each slot advances by its accepted length plus
   one correction/bonus token (variable per slot; shapes stay static,
   parked rows just write to nowhere).  Rejected tail positions roll
   back: KV caches are SET-written by the verify scatter, so a rewind of
   ``positions`` suffices (the stale tail sits beyond the causal mask
   and the next set-write overwrites it exactly); paged caches also
   return over-mapped tail pages to the allocator
   (``BlockAllocator.trim_slot``); recurrent SSM/conv state cannot
   rewind and is re-committed from per-position snapshots instead.

Invariants: a draft token is accepted under greedy sampling iff it
equals the target argmax at its position, and the verify logits are
computed by the same per-position reductions as the decode step — so
greedy speculative streams are **bit-identical** to the non-speculative
engine no matter how bad the draft is (the draft only moves the
acceptance rate, i.e. how many target dispatches each token costs).
Temperature sampling uses standard rejection sampling, which preserves
the target distribution exactly.  ``stats["drafted"/"accepted"/
"acceptance_rate"]`` track draft quality.

Sampling (``sampler.py``) is shared between the fused decode step and the
admission path: greedy, or temperature with top-k / top-p filtering.
Decode ticks and admissions draw from disjoint chained ``fold_in``
streams, so tick counters and request ids can never collide.

Stats keys <-> registry metrics
-------------------------------
``Engine.stats`` keeps its historical flat-dict surface but is a
:class:`repro.obs.metrics.StatsView` over a per-engine metric registry
(``Engine(..., obs=Observability(...))``; the authoritative key->metric
table is ``repro.serving.engine.STATS_METRICS``).  Every key below reads
(and, except where noted, writes) the registry metric on the right:

===================  ====================================  =============
stats key            registry metric                       kind
===================  ====================================  =============
prefill_dispatches   serve_prefill_dispatches_total        counter
decode_ticks         serve_decode_ticks_total              counter
tokens_out           serve_tokens_out_total                counter
finished             serve_finished_total                  counter
preempted            serve_preempted_total                 counter
requeued             serve_requeued_total                  counter
timeout              serve_timeout_total                   counter
rejected             serve_rejected_total                  counter
deadline_preempts    serve_deadline_preempts_total         counter
corrupt_ticks        serve_corrupt_ticks_total             counter
stalled_slot_ticks   serve_stalled_slot_ticks_total        counter
degrade_down         serve_degrade_down_total              counter
degrade_up           serve_degrade_up_total                counter
degrade_level        serve_degrade_level                   gauge
prefill_s            serve_prefill_seconds_total           counter
decode_s             serve_decode_seconds_total            counter
drafted              serve_spec_drafted_total              counter
accepted             serve_spec_accepted_total             counter
acceptance_rate      serve_acceptance_rate                 derived gauge
                                                           (READ-ONLY:
                                                           accepted /
                                                           drafted at
                                                           read time)
attn_gather_bytes    serve_attn_gather_bytes_total         counter
attn_kernel_bytes    serve_attn_kernel_bytes_total         counter
===================  ====================================  =============

Latency histograms (``serve_ttft_seconds``, ``serve_tpot_seconds``,
``serve_tick_seconds``) have no stats key — read them off the engine's
registry (``obs.registry.get(name)``); the overload bench reports its
percentiles from them.  The full metric glossary, including the
process-global kernel/autotune/training names, lives in
``repro/obs/__init__.py``.
"""

from repro.dist.steps import (  # noqa: F401
    make_prefill_step,
    make_serve_step,
    make_verify_step,
)
from repro.serving.blocks import BlockAllocator  # noqa: F401
from repro.serving.engine import Engine  # noqa: F401
from repro.serving.faults import FaultPlan  # noqa: F401
from repro.serving.request import (  # noqa: F401
    FinishReason,
    Request,
    RequestStatus,
)
from repro.serving.sampler import (  # noqa: F401
    apply_top_k,
    apply_top_p,
    sample,
)
from repro.serving.scheduler import Scheduler  # noqa: F401
