"""Serving substrate (see also repro/launch/serve.py).

The decode machinery lives with its models (KV caches in
repro/models/attention.py, SSM state caches in repro/models/mamba2.py) and
the step builder in repro/dist/steps.py; this package re-exports the
public serving surface.
"""

from repro.dist.steps import make_serve_step  # noqa: F401
