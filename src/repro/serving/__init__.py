"""Serving engine: batched prefill + continuous batching over the model zoo.

Why this package exists: ACDC's pitch is cheap inference — O(N) parameters,
O(N log N) operations per structured projection — and the serving layer is
where that cost advantage is actually cashed in.  This package turns the
model zoo's decode machinery (KV caches in ``repro/models/attention.py``,
SSM/conv state in ``repro/models/mamba2.py``) into an engine.

Request lifecycle
-----------------
A :class:`Request` (``request.py``) carries a ragged-length prompt plus its
stop conditions (``eos_id``, ``max_new_tokens``).  ``Engine.submit``
validates it and hands it to the FIFO :class:`Scheduler` (``scheduler.py``)
as QUEUED.  When a batch slot frees up it becomes ACTIVE: one lowered
**prefill** program (``make_prefill_step``) runs the whole prompt, scatters
the resulting KV / SSM state into the slot's cache row, and samples the
first token — the time-to-first-token mark.  Each subsequent engine tick
advances it one token; EOS / token-budget / cache-ceiling stops flip it to
FINISHED (``finish_reason``) and release the slot.

Slot model
----------
The :class:`Engine` (``engine.py``) owns a fixed-shape cache with
``n_slots`` batch rows (max_len positions each).  Prefill writes a slot's
entire row — positions at or beyond the prompt length are zeroed, because
the decode path scatters additively — so slots are reused without a reset
pass.  Free slots ride through decode parked at ``position = max_len``,
where the one-hot scatter writes nothing.  Per-request compute is
batch-row-independent, so outputs are identical to running each request
alone (pinned by tests/test_serving_engine.py).

Tick loop
---------
``tick()`` = admit (0+ prefill dispatches, one per admission) + one fused
decode step over all ``n_slots`` rows + evict.  All shapes are static, so
the engine compiles exactly two programs — one prefill, one decode — no
matter how traffic arrives.  ``run(requests)`` ticks until drained.

Sampling (``sampler.py``) is shared between the fused decode step and the
admission path: greedy, or temperature with top-k / top-p filtering.
"""

from repro.dist.steps import make_prefill_step, make_serve_step  # noqa: F401
from repro.serving.engine import Engine  # noqa: F401
from repro.serving.request import Request, RequestStatus  # noqa: F401
from repro.serving.sampler import (  # noqa: F401
    apply_top_k,
    apply_top_p,
    sample,
)
from repro.serving.scheduler import Scheduler  # noqa: F401
