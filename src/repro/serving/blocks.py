"""Paged block KV cache: host-side allocator + block tables.

Why: ACDC makes the projections nearly free, so at serving time the
dominant allocation is the KV cache — and the dense layout pays worst-case
memory: every slot owns a ``max_len`` slab even when most requests are
short.  Paging splits the cache into fixed-size blocks of ``block_size``
token positions drawn from ONE global pool, so a 10-token request holds
one block while a 500-token request holds 32, and the pool is sized for
the *mix*, not ``n_slots * max_len``.

Layout contract (shared with ``repro.models.attention``):

* The device pool is ``(n_layers, n_blocks + 1, block_size, Hkv, Dh)`` per
  K and V (:func:`repro.models.attention.init_kv_cache_paged`).  Physical
  page ``n_blocks`` is the **write sink** ("trash"): decode writes from
  parked or stalled slots land there and are never read back.  The
  allocator only hands out ids ``0 .. n_blocks - 1``.
* The block table is a static ``(n_slots, max_blocks_per_slot)`` int32
  array; entry ``[slot, i]`` is the physical page holding the slot's token
  positions ``[i * block_size, (i + 1) * block_size)``, or ``-1`` when
  unmapped.  The table lives on the host (the allocator mutates it in
  place) and is shipped to the device each tick as a tiny int32 array.
* Stale page contents are never zeroed: the decode scatter writes with
  ``set`` (not add) and the causal mask hides every position beyond the
  slot's write frontier, so a freed page can be remapped as-is.

Admission contract: a request may only be admitted when
``blocks_for(prompt_len + 1)`` pages are free — its prompt plus room for
the first decode token, so admission can never strand a request that has
nowhere to write token one.  Decode growth allocates lazily: the engine
calls :meth:`BlockAllocator.ensure` before each tick; when the pool is dry
the slot *stalls* (parks for the tick, generating nothing) rather than
corrupting another slot's pages, and resumes once an eviction frees pages.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs import trace


class BlockAllocator:
    """Fixed-size block pool with a global free list and per-slot tables.

    ``fault`` (a :class:`repro.serving.faults.FaultPlan`, default None =
    no-op) lets chaos tests make capacity checks and page mapping report a
    dry pool even when pages are free — injected *before* any page is
    handed out, so the allocator's own invariants (checkable any time via
    :meth:`audit`) hold under any plan.
    """

    def __init__(self, n_blocks: int, block_size: int, n_slots: int,
                 max_blocks_per_slot: int, fault: Optional[object] = None):
        if n_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of at least one token")
        if max_blocks_per_slot < 1:
            raise ValueError("need at least one block per slot")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_slots = n_slots
        self.max_blocks_per_slot = max_blocks_per_slot
        self.fault = fault
        #: physical index of the write-sink page (pool allocates one extra)
        self.trash = n_blocks
        # LIFO free list: recently freed pages are remapped first, which
        # keeps the working set of hot pages small
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._held: set = set()
        self.table = np.full((n_slots, max_blocks_per_slot), -1, np.int32)
        self.peak_in_use = 0

    # -- capacity queries --------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._held)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_admit(self, prompt_len: int) -> bool:
        """Enough free pages for the prompt plus the first decode token?"""
        if self.fault is not None and self.fault.alloc_fail():
            return False
        need = min(self.blocks_for(prompt_len + 1), self.max_blocks_per_slot)
        return self.n_free >= need

    def blocks_held(self, slot: int) -> int:
        return int((self.table[slot] >= 0).sum())

    def _release(self, slot: int, idx: int) -> None:
        """Unmap table entry ``idx`` of ``slot`` and return its page to
        the free list (single home for the release bookkeeping)."""
        blk = int(self.table[slot, idx])
        self.table[slot, idx] = -1
        self._held.discard(blk)
        self._free.append(blk)

    # -- allocation --------------------------------------------------------

    def _pop(self) -> int:
        blk = self._free.pop()
        self._held.add(blk)
        self.peak_in_use = max(self.peak_in_use, len(self._held))
        return blk

    def alloc_slot(self, slot: int, prompt_len: int) -> None:
        """Map the admission's pages: prompt + first decode token."""
        if (self.table[slot] >= 0).any():
            raise ValueError(f"slot {slot} still holds blocks")
        need = min(self.blocks_for(prompt_len + 1), self.max_blocks_per_slot)
        if need > self.n_free:
            raise ValueError(
                f"slot {slot}: need {need} blocks, {self.n_free} free "
                "(admission must be gated on can_admit)")
        for i in range(need):
            self.table[slot, i] = self._pop()

    def ensure(self, slot: int, position: int) -> bool:
        """Make sure the page covering ``position`` is mapped.

        Returns False when the position needs a fresh page and the pool is
        dry — the caller must stall the slot for this tick.  Positions at
        or beyond the virtual row length are parked writes that the device
        routes to the trash page; they need no mapping.
        """
        return self.ensure_range(slot, position, 1)

    def ensure_range(self, slot: int, start: int, count: int) -> bool:
        """Map every page covering positions ``[start, start + count)``
        — the speculative verify window writes ``k + 1`` positions in one
        program.  All-or-nothing: on a dry pool, pages mapped by THIS call
        are returned and False comes back (the caller stalls the slot;
        a partially-mapped window would verify against trash).  Positions
        beyond the virtual row length are trash-routed and need no map.
        """
        if self.fault is not None and self.fault.alloc_fail():
            return False    # injected dry pool: caller stalls the slot
        newly: List[int] = []
        for pos in range(start, start + count):
            if pos >= self.max_blocks_per_slot * self.block_size:
                break
            idx = pos // self.block_size
            if self.table[slot, idx] >= 0:
                continue
            if not self._free:
                for idx2 in newly:
                    self._release(slot, idx2)
                return False
            self.table[slot, idx] = self._pop()
            newly.append(idx)
        return True

    def trim_slot(self, slot: int, n_tokens: int) -> int:
        """Return over-mapped tail pages to the pool — speculative-decode
        rollback: after a verify that mapped ``k + 1`` positions commits
        only ``n_tokens`` total for the slot, pages beyond the first
        ``ceil(n_tokens / block_size)`` hold nothing but rejected-tail
        junk.  Returns the number of pages freed.
        """
        keep = self.blocks_for(max(n_tokens, 1))
        freed = 0
        for idx in range(keep, self.max_blocks_per_slot):
            if self.table[slot, idx] < 0:
                continue
            self._release(slot, idx)
            freed += 1
        return freed

    # -- release -----------------------------------------------------------

    def free_slot(self, slot: int) -> None:
        row = self.table[slot]
        idxs = [i for i in range(self.max_blocks_per_slot) if row[i] >= 0]
        if not idxs:
            raise ValueError(f"slot {slot} holds no blocks (double free?)")
        for idx in idxs:
            blk = int(row[idx])
            if blk not in self._held:
                raise ValueError(f"block {blk} double-freed (slot {slot})")
            self._release(slot, idx)

    # -- invariants --------------------------------------------------------

    def audit(self) -> Dict[str, int]:
        """Full-pool consistency check; raises AssertionError on the first
        violation, returns a summary when clean.

        Invariants (the ones every release path — evict, preempt-requeue,
        ``trim_slot``, all-stalled deadlock eviction, ``ensure_range``
        rollback — must preserve, asserted after every chaos run):

        * the free list holds no duplicates and no held page;
        * free + held partition exactly the ``n_blocks`` real pages
          (no leaks out of the pool, no phantom pages into it);
        * every mapped table entry is a real held page, mapped exactly
          once across the whole table (no double-maps, no stale maps of
          freed pages), and the trash page is never mapped;
        * every held page is mapped somewhere (held-but-unmapped would be
          a leak: unreachable until process exit).
        """
        free = list(self._free)
        if len(free) != len(set(free)):
            raise AssertionError("duplicate pages in the free list")
        freeset = set(free)
        if freeset & self._held:
            raise AssertionError(
                f"pages both free and held: {sorted(freeset & self._held)}")
        universe = set(range(self.n_blocks))
        if freeset | self._held != universe:
            raise AssertionError(
                f"pages leaked from the pool: "
                f"{sorted(universe - freeset - self._held)}")
        mapped = [int(b) for b in self.table.ravel() if b >= 0]
        if len(mapped) != len(set(mapped)):
            dup = sorted(b for b in set(mapped) if mapped.count(b) > 1)
            raise AssertionError(f"pages double-mapped: {dup}")
        bad = [b for b in mapped if b >= self.n_blocks or b < 0]
        if bad:
            raise AssertionError(f"table maps non-pool pages: {sorted(bad)}")
        if set(mapped) != self._held:
            raise AssertionError(
                f"table/held mismatch: stale maps "
                f"{sorted(set(mapped) - self._held)}, leaked holds "
                f"{sorted(self._held - set(mapped))}")
        summary = {"free": len(free), "held": len(self._held),
                   "mapped": len(mapped)}
        trace.instant_global("allocator", "audit", **summary)
        return summary

    # -- device view -------------------------------------------------------

    def phys_row(self, slot: int) -> np.ndarray:
        """Table row with unmapped entries routed to the trash page —
        the layout the prefill page-scatter writes through."""
        row = self.table[slot]
        return np.where(row >= 0, row, self.trash).astype(np.int32)
