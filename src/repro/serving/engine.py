"""Continuous-batching serving engine over the model zoo's decode caches.

The engine owns a fixed-shape cache with ``n_slots`` batch rows and runs a
tick loop:

1. **admit** — while a slot is free and requests are queued, the oldest
   request is admitted: ONE lowered prefill program runs its whole
   (right-padded) prompt, the resulting per-slot KV / SSM state is
   scattered into the slot's cache row, and the first token is sampled
   from the last-position logits (this is also the time-to-first-token
   mark);
2. **decode** — one fused decode step advances EVERY active slot by one
   token; free slots ride along parked at ``position = max_len`` where the
   one-hot cache scatter writes nothing;
3. **evict** — requests that hit EOS, their ``max_new_tokens`` budget, or
   the cache ceiling release their slot immediately, so the next tick's
   admission refills the batch.

All shapes are static — prompts pad to ``max_prompt_len``, the decode batch
is always ``n_slots`` wide — so the engine compiles exactly two programs
(one prefill, one decode) regardless of traffic.  Per-request compute is
batch-row-independent (each slot attends only to its own cache row), so a
request's output stream is identical to running it alone; the engine test
pins that down.
"""

from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import steps as steps_mod
from repro.serving import sampler as sampler_mod
from repro.serving.request import Request, RequestStatus
from repro.serving.scheduler import Scheduler


class Engine:
    def __init__(
        self,
        model,
        cfg,
        params,
        n_slots: int = 4,
        max_len: int = 128,
        max_prompt_len: Optional[int] = None,
        sample: str = "greedy",
        temperature: float = 1.0,
        top_k: int = 0,
        top_p: float = 0.0,
        rng: Optional[jax.Array] = None,
    ):
        if model.prefill is None or model.decode_step is None:
            raise ValueError(f"family {cfg.family!r} cannot serve")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_prompt_len = max_prompt_len or max_len // 2
        self.scheduler = Scheduler(n_slots)
        self._rng = jax.random.PRNGKey(0) if rng is None else rng

        self._cache = model.init_cache(cfg, n_slots, max_len)
        # template for per-admission prefill: batch-1, same max_len slabs
        self._slot_template = model.init_cache(cfg, 1, max_len)
        self._tokens = np.zeros((n_slots,), np.int32)
        self._positions = np.full((n_slots,), max_len, np.int32)  # parked

        # the big cache is donated through decode/insert: it is the dominant
        # serving allocation and both calls replace self._cache wholesale,
        # so XLA can update the buffers in place instead of copying the
        # whole multi-layer slab every tick
        self._prefill = jax.jit(steps_mod.make_prefill_step(model, cfg))
        self._decode = jax.jit(steps_mod.make_serve_step(
            model, cfg, sample=sample, temperature=temperature,
            top_k=top_k, top_p=top_p), donate_argnums=(1,))
        self._sample = jax.jit(functools.partial(
            sampler_mod.sample, method=sample, temperature=temperature,
            top_k=top_k, top_p=top_p))

        def insert(cache, slot_cache, slot):
            return jax.tree.map(
                lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                    c, s.astype(c.dtype), slot, axis=1),
                cache, slot_cache)

        self._insert = jax.jit(insert, donate_argnums=(0,))
        self.stats = {"prefill_dispatches": 0, "decode_ticks": 0,
                      "tokens_out": 0, "finished": 0}

    # -- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.prompt_len < 1:
            raise ValueError(f"request {request.rid}: empty prompt")
        if request.prompt_len > self.max_prompt_len:
            raise ValueError(
                f"request {request.rid}: prompt {request.prompt_len} > "
                f"max_prompt_len {self.max_prompt_len}")
        if self.cfg.family == "encdec" and request.frontend_embeds is None:
            # without frames the cross-KV stays all-zero: the request would
            # "succeed" while conditioning on a null encoder
            raise ValueError(
                f"request {request.rid}: encdec family needs "
                f"frontend_embeds")
        request.t_submit = time.time()
        self.scheduler.submit(request)

    # -- tick loop --------------------------------------------------------

    def tick(self) -> int:
        """Admit + one fused decode step; returns #active slots advanced."""
        for slot, req in self.scheduler.admit():
            self._admit(slot, req)
        active = self.scheduler.active()
        if active:
            rng = jax.random.fold_in(self._rng, 1 << 20
                                     | self.stats["decode_ticks"])
            tok, self._cache = self._decode(
                self.params, self._cache, jnp.asarray(self._tokens),
                jnp.asarray(self._positions), rng)
            tok_np = np.asarray(tok)
            self.stats["decode_ticks"] += 1
            now = time.time()
            for slot, req in active:
                t = int(tok_np[slot])
                req.generated.append(t)
                self.stats["tokens_out"] += 1
                self._positions[slot] += 1
                self._tokens[slot] = t
                self._maybe_finish(slot, req, t, now)
        return len(active)

    def run(self, requests: Sequence[Request],
            max_ticks: Optional[int] = None) -> List[Request]:
        """Submit everything, tick until drained, return the requests."""
        for r in requests:
            self.submit(r)
        ticks = 0
        while self.scheduler.has_work:
            self.tick()
            ticks += 1
            if max_ticks is not None and ticks > max_ticks:
                raise RuntimeError(f"engine not drained after {ticks} ticks")
        return list(requests)

    # -- internals --------------------------------------------------------

    def _admit(self, slot: int, req: Request) -> None:
        p = self.max_prompt_len
        toks = np.zeros((1, p), np.int32)
        toks[0, : req.prompt_len] = np.asarray(req.prompt, np.int32)
        lengths = jnp.asarray([req.prompt_len], jnp.int32)
        fe = getattr(req, "frontend_embeds", None)
        last_logits, slot_cache = self._prefill(
            self.params, self._slot_template, jnp.asarray(toks), lengths, fe)
        self.stats["prefill_dispatches"] += 1
        self._cache = self._insert(self._cache, slot_cache,
                                   jnp.int32(slot))
        tok = int(self._sample(jax.random.fold_in(self._rng, req.rid),
                               last_logits)[0])
        req.t_first_token = time.time()
        req.generated.append(tok)
        self.stats["tokens_out"] += 1
        self._tokens[slot] = tok
        self._positions[slot] = req.prompt_len
        self._maybe_finish(slot, req, tok, req.t_first_token)

    def _maybe_finish(self, slot: int, req: Request, last_token: int,
                      now: float) -> None:
        reason = None
        if req.eos_id is not None and last_token == req.eos_id:
            reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            reason = "length"
        elif self._positions[slot] >= self.max_len:
            reason = "cache_full"   # no room to write the next token
        if reason is None:
            return
        req.status = RequestStatus.FINISHED
        req.finish_reason = reason
        req.t_finish = now
        self.scheduler.release(slot)
        self._positions[slot] = self.max_len      # park: no cache writes
        self.stats["finished"] += 1
