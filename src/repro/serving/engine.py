"""Continuous-batching serving engine over the model zoo's decode caches.

The engine owns a fixed-shape cache with ``n_slots`` batch rows and runs a
tick loop:

1. **admit** — while a slot is free and requests are queued, the oldest
   request is admitted: ONE lowered prefill program runs its whole
   (right-padded) prompt, the resulting per-slot KV / SSM state is
   scattered into the slot's cache row, and the first token is sampled
   from the last-position logits (this is also the time-to-first-token
   mark);
2. **decode** — one fused decode step advances EVERY active slot by one
   token; free slots ride along parked at the row length where the cache
   scatter writes nothing;
3. **evict** — requests that hit EOS, their ``max_new_tokens`` budget, or
   the cache ceiling release their slot immediately, so the next tick's
   admission refills the batch.

All shapes are static — prompts pad to ``max_prompt_len``, the decode batch
is always ``n_slots`` wide — so the engine compiles exactly two programs
(one prefill, one decode) regardless of traffic.  Per-request compute is
batch-row-independent (each slot attends only to its own cache row), so a
request's output stream is identical to running it alone; the engine test
pins that down.

Paged mode (``paged=True``) swaps the dense per-slot ``max_len`` slabs for
a global pool of ``block_size``-token pages managed by
:class:`repro.serving.blocks.BlockAllocator`: admission is gated on free
blocks for the prompt plus one decode token, decode growth maps pages
lazily, and a slot whose next page cannot be mapped *stalls* (parks for
the tick, producing nothing) until an eviction frees pages — so the pool
can be sized for the traffic mix instead of ``n_slots * max_len`` while
greedy output streams stay identical to the dense cache.  If every active
slot is stalled at once the engine breaks the deadlock by evicting the
stalled request holding the most pages (``finish_reason="cache_full"``,
counted in ``stats["preempted"]``).

Speculative mode (``spec_k > 0``) replaces the one-token decode tick with
draft -> verify -> accept/rollback: a cheap draft source
(:mod:`repro.spec.draft`, default the target's own truncated ACDC
cascades) proposes ``spec_k`` tokens per slot in one fused program, ONE
target verify program scores and commits them
(:func:`repro.dist.steps.make_verify_step`), and each slot advances by
its accepted length — variable per slot, shapes static via masking.
Greedy streams stay bit-identical to the non-speculative engine; see
:mod:`repro.serving` for the tick contract.
"""

from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import steps as steps_mod
from repro.serving import sampler as sampler_mod
from repro.serving.blocks import BlockAllocator
from repro.serving.request import Request, RequestStatus
from repro.serving.scheduler import Scheduler


class Engine:
    def __init__(
        self,
        model,
        cfg,
        params,
        n_slots: int = 4,
        max_len: int = 128,
        max_prompt_len: Optional[int] = None,
        sample: str = "greedy",
        temperature: float = 1.0,
        top_k: int = 0,
        top_p: float = 0.0,
        rng: Optional[jax.Array] = None,
        paged: bool = False,
        block_size: int = 16,
        n_blocks: Optional[int] = None,
        admit_window: int = 4,
        spec_k: int = 0,
        draft=None,
        draft_depth: Optional[int] = None,
        draft_skip_layers: int = 0,
    ):
        if model.prefill is None or model.decode_step is None:
            raise ValueError(f"family {cfg.family!r} cannot serve")
        if paged and (model.init_cache_paged is None
                      or model.decode_step_paged is None):
            raise ValueError(
                f"family {cfg.family!r} has no paged KV cache (its decode "
                "state is not length-proportional); serve it dense")
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0 (0 disables)")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_prompt_len = max_prompt_len or max_len // 2
        self.paged = paged
        self._rng = jax.random.PRNGKey(0) if rng is None else rng
        # disjoint RNG streams: decode-tick keys chain through fold_in(_, 0)
        # and admission keys through fold_in(_, 1), so a tick counter can
        # never collide with a request id (bit-packing both into one fold
        # value was non-injective: tick 2**20 reused tick 0's key and
        # rid >= 2**20 collided with decode keys)
        self._rng_decode = jax.random.fold_in(self._rng, 0)
        self._rng_admit = jax.random.fold_in(self._rng, 1)

        if paged:
            self.block_size = block_size
            self.max_blocks = -(-max_len // block_size)
            # virtual per-slot row length: max_len rounded up to whole
            # pages; the engine still stops requests at max_len, the tail
            # padding just keeps the page-wise gather rectangular
            self._virtual = self.max_blocks * block_size
            if n_blocks is None:
                n_blocks = n_slots * self.max_blocks  # dense-parity pool
            min_pool = -(-(self.max_prompt_len + 1) // block_size)
            if n_blocks < min_pool:
                raise ValueError(
                    f"pool of {n_blocks} blocks cannot admit a "
                    f"max_prompt_len={self.max_prompt_len} request "
                    f"(needs {min_pool})")
            self.allocator = BlockAllocator(n_blocks, block_size, n_slots,
                                            self.max_blocks)
            self.scheduler = Scheduler(
                n_slots,
                admit_ok=lambda r: self.allocator.can_admit(r.prompt_len),
                window=admit_window)
            self._park = self._virtual
            self._cache = model.init_cache_paged(cfg, n_slots, n_blocks,
                                                 block_size)
            # batch-1 dense template the admission prefill writes through
            # before the in-program page scatter
            self._slot_template = model.init_cache(cfg, 1, self._virtual)
            self._prefill = jax.jit(steps_mod.make_prefill_step(
                model, cfg, paged=True), donate_argnums=(1,))
            self._decode = jax.jit(steps_mod.make_serve_step(
                model, cfg, sample=sample, temperature=temperature,
                top_k=top_k, top_p=top_p, paged=True), donate_argnums=(1,))
            self._insert = None
        else:
            self.allocator = None
            self.scheduler = Scheduler(n_slots)
            self._park = max_len
            self._cache = model.init_cache(cfg, n_slots, max_len)
            # template for per-admission prefill: batch-1, same max_len slabs
            self._slot_template = model.init_cache(cfg, 1, max_len)
            # the big cache is donated through decode/insert: it is the
            # dominant serving allocation and both calls replace
            # self._cache wholesale, so XLA can update the buffers in
            # place instead of copying the whole multi-layer slab per tick
            self._prefill = jax.jit(steps_mod.make_prefill_step(model, cfg))
            self._decode = jax.jit(steps_mod.make_serve_step(
                model, cfg, sample=sample, temperature=temperature,
                top_k=top_k, top_p=top_p), donate_argnums=(1,))
            self._insert = steps_mod.make_insert_step()

        self._tokens = np.zeros((n_slots,), np.int32)
        self._positions = np.full((n_slots,), self._park, np.int32)
        self._stalled: Set[int] = set()
        self._sample = jax.jit(functools.partial(
            sampler_mod.sample, method=sample, temperature=temperature,
            top_k=top_k, top_p=top_p))
        self.stats = {"prefill_dispatches": 0, "decode_ticks": 0,
                      "tokens_out": 0, "finished": 0, "preempted": 0,
                      "stalled_slot_ticks": 0,
                      "prefill_s": 0.0, "decode_s": 0.0,
                      "drafted": 0, "accepted": 0, "acceptance_rate": 0.0,
                      "attn_gather_bytes": 0, "attn_kernel_bytes": 0}

        self.spec_k = spec_k
        self.draft = None
        if spec_k:
            vfn = model.verify_step_paged if paged else model.verify_step
            if vfn is None:
                raise ValueError(
                    f"family {cfg.family!r} has no "
                    f"{'paged ' if paged else ''}speculative verify path")
            if draft is None:
                # paper-native default: the target's own cascades truncated
                # to half depth (sections 3-4 depth result)
                from repro.spec.draft import TruncatedCascadeDraft
                depth = (draft_depth if draft_depth is not None
                         else max(1, cfg.sell_k // 2))
                draft = TruncatedCascadeDraft(cfg, params, depth=depth,
                                              skip_layers=draft_skip_layers)
            self.draft = draft
            self.draft.prepare(n_slots, self.max_len, spec_k, sample,
                               temperature, top_k, top_p)
            self._verify = jax.jit(steps_mod.make_verify_step(
                model, cfg, sample=sample, temperature=temperature,
                top_k=top_k, top_p=top_p, paged=paged, park=self._park),
                donate_argnums=(1,))

    # -- accounting --------------------------------------------------------

    @property
    def cache_bytes(self) -> int:
        """Bytes held by the decode cache (the dominant serving
        allocation): dense slabs or the paged pool, whichever is live —
        plus the draft's dense slot cache in speculative mode, so the
        self-draft's memory cost stays visible next to a paged pool."""
        total = sum(leaf.nbytes for leaf in jax.tree.leaves(self._cache))
        if self.draft is not None:
            total += self.draft.cache_bytes
        return total

    def _attn_bytes_tick(self, pos: np.ndarray) -> None:
        """Analytic attention K/V traffic for one paged decode/verify tick,
        accumulated into ``stats`` (model, not a measurement):

        * ``attn_gather_bytes`` — what the block-table *gather* path reads:
          every K/V page pool is materialised as a ``(n_slots, virtual,
          Hkv, Dh)`` view, so each layer costs ``n_slots * virtual`` tokens
          regardless of how full any row is (O(max_blocks * block_size)
          per slot).
        * ``attn_kernel_bytes`` — what the fused streaming kernel reads:
          per live row, only the mapped prefix ``ceil(pos / block_size)``
          pages; parked and stalled rows cost nothing.  Window narrowing
          and the chunk-granularity round-up are ignored, so this is a
          slight over-estimate for sliding-window layers.

        Both counters advance every paged tick whichever path actually
        ran, so fused and gather runs of the same trace report identical
        numbers and the ratio is a pure memory-model statement.
        """
        gather = kernel = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._cache)[0]:
            if not any("pages" in str(k) for k in path):
                continue
            n_layers, bs = leaf.shape[0], leaf.shape[2]
            tok_bytes = int(np.prod(leaf.shape[3:])) * leaf.dtype.itemsize
            gather += n_layers * self.n_slots * self._virtual * tok_bytes
            for p in pos:
                p = int(p)
                if p < self._virtual:
                    kernel += n_layers * (-(-p // bs) * bs) * tok_bytes
        self.stats["attn_gather_bytes"] += gather
        self.stats["attn_kernel_bytes"] += kernel

    def _decode_rng(self, tick: int) -> jax.Array:
        return jax.random.fold_in(self._rng_decode, tick)

    def _admit_rng(self, rid: int) -> jax.Array:
        return jax.random.fold_in(self._rng_admit, rid)

    # -- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.prompt_len < 1:
            raise ValueError(f"request {request.rid}: empty prompt")
        if request.prompt_len > self.max_prompt_len:
            raise ValueError(
                f"request {request.rid}: prompt {request.prompt_len} > "
                f"max_prompt_len {self.max_prompt_len}")
        if self.cfg.family == "encdec" and request.frontend_embeds is None:
            # without frames the cross-KV stays all-zero: the request would
            # "succeed" while conditioning on a null encoder
            raise ValueError(
                f"request {request.rid}: encdec family needs "
                f"frontend_embeds")
        request.t_submit = time.time()
        self.scheduler.submit(request)

    # -- tick loop --------------------------------------------------------

    def _admit_and_map(self) -> None:
        """Admission pass + (paged) mapping of this tick's write window."""
        if self.paged:
            # one at a time: each admission's block allocation must be
            # visible to the next can_admit capacity check
            while True:
                admitted = self.scheduler.admit(limit=1)
                if not admitted:
                    break
                self._admit(*admitted[0])
            self._ensure_blocks(need=self.spec_k + 1)
        else:
            for slot, req in self.scheduler.admit():
                self._admit(slot, req)

    def tick(self) -> int:
        """Admit + one fused decode step; returns #active slots advanced."""
        if self.spec_k:
            return self._tick_spec()
        self._admit_and_map()
        active = self.scheduler.active()
        if active:
            rng = self._decode_rng(self.stats["decode_ticks"])
            t0 = time.perf_counter()
            if self.paged:
                pos = self._positions.copy()
                for slot in self._stalled:
                    pos[slot] = self._park  # no write, no token this tick
                self._attn_bytes_tick(pos)
                tok, self._cache = self._decode(
                    self.params, self._cache, jnp.asarray(self._tokens),
                    jnp.asarray(pos), jnp.asarray(self.allocator.table), rng)
            else:
                tok, self._cache = self._decode(
                    self.params, self._cache, jnp.asarray(self._tokens),
                    jnp.asarray(self._positions), rng)
            tok_np = np.asarray(tok)
            self.stats["decode_s"] += time.perf_counter() - t0
            self.stats["decode_ticks"] += 1
            self.stats["stalled_slot_ticks"] += len(self._stalled)
            now = time.time()
            for slot, req in active:
                if slot in self._stalled:
                    continue  # parked this tick: its sampled token is junk
                t = int(tok_np[slot])
                req.generated.append(t)
                self.stats["tokens_out"] += 1
                self._positions[slot] += 1
                self._tokens[slot] = t
                self._maybe_finish(slot, req, t, now)
        return len(active)

    def _tick_spec(self) -> int:
        """One speculative tick: draft k, verify once, advance each slot
        by its accepted length, roll back the rest."""
        k = self.spec_k
        self._admit_and_map()
        active = self.scheduler.active()
        if not active:
            return 0
        tick_rng = self._decode_rng(self.stats["decode_ticks"])
        draft_rng = jax.random.fold_in(tick_rng, 0)
        verify_rng = jax.random.fold_in(tick_rng, 1)
        pos = self._positions.copy()
        for slot in self._stalled:
            pos[slot] = self._park  # no writes, no tokens this tick
        if self.paged:
            self._attn_bytes_tick(pos)

        t0 = time.perf_counter()
        drafts, draft_logits = self.draft.propose(self._tokens, pos,
                                                  draft_rng)
        tok_mat = np.concatenate([self._tokens[:, None], drafts],
                                 axis=1).astype(np.int32)
        if self.paged:
            acc, out, self._cache = self._verify(
                self.params, self._cache, jnp.asarray(tok_mat),
                jnp.asarray(drafts), draft_logits, jnp.asarray(pos),
                jnp.asarray(self.allocator.table), verify_rng)
        else:
            acc, out, self._cache = self._verify(
                self.params, self._cache, jnp.asarray(tok_mat),
                jnp.asarray(drafts), draft_logits, jnp.asarray(pos),
                verify_rng)
        acc_np = np.asarray(acc)
        out_np = np.asarray(out)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_ticks"] += 1
        self.stats["stalled_slot_ticks"] += len(self._stalled)

        now = time.time()
        n_adv = np.zeros((self.n_slots,), np.int32)
        for slot, req in active:
            if slot in self._stalled:
                continue
            n = int(acc_np[slot])
            self.stats["drafted"] += k
            self.stats["accepted"] += n
            # commit the accepted drafts plus the correction/bonus token,
            # applying the per-token stop rules in stream order so EOS /
            # budget / ceiling cut the stream exactly where the
            # non-speculative engine would
            for i in range(n + 1):
                t = int(out_np[slot, i])
                req.generated.append(t)
                self.stats["tokens_out"] += 1
                self._positions[slot] += 1
                self._tokens[slot] = t
                n_adv[slot] += 1
                self._maybe_finish(slot, req, t, now)
                if req.done:
                    break
        if self.stats["drafted"]:
            self.stats["acceptance_rate"] = (self.stats["accepted"]
                                             / self.stats["drafted"])
        self.draft.commit(n_adv)
        if self.paged:
            # rollback: return verify-window pages beyond each surviving
            # slot's committed frontier (finished slots already freed all).
            # +1 keeps the page the NEXT tick writes first: releasing it on
            # a page-boundary frontier would let the admission pass snatch
            # it back and spuriously stall (or even preempt) this slot.
            for slot, req in active:
                if not req.done and slot not in self._stalled:
                    self.allocator.trim_slot(
                        slot, int(self._positions[slot]) + 1)
        return len(active)

    def run(self, requests: Sequence[Request],
            max_ticks: Optional[int] = None) -> List[Request]:
        """Submit everything, tick until drained, return the requests."""
        for r in requests:
            self.submit(r)
        ticks = 0
        while self.scheduler.has_work:
            if max_ticks is not None and ticks >= max_ticks:
                raise RuntimeError(f"engine not drained after {ticks} ticks")
            self.tick()
            ticks += 1
        return list(requests)

    # -- internals --------------------------------------------------------

    def _admit(self, slot: int, req: Request) -> None:
        p = self.max_prompt_len
        toks = np.zeros((1, p), np.int32)
        toks[0, : req.prompt_len] = np.asarray(req.prompt, np.int32)
        lengths = jnp.asarray([req.prompt_len], jnp.int32)
        fe = getattr(req, "frontend_embeds", None)
        t0 = time.perf_counter()
        if self.paged:
            self.allocator.alloc_slot(slot, req.prompt_len)
            last_logits, self._cache = self._prefill(
                self.params, self._cache, self._slot_template,
                jnp.asarray(toks), lengths,
                jnp.asarray(self.allocator.phys_row(slot)),
                jnp.int32(slot), fe)
        else:
            last_logits, slot_cache = self._prefill(
                self.params, self._slot_template, jnp.asarray(toks), lengths,
                fe)
            self._cache = self._insert(self._cache, slot_cache,
                                       jnp.int32(slot))
        tok = int(self._sample(self._admit_rng(req.rid), last_logits)[0])
        if self.draft is not None:
            # the draft mirrors the slot layout: its own (cheap) prefill
            # fills its cache row so drafting starts from the same prompt
            self.draft.prefill(slot, jnp.asarray(toks), lengths, fe)
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_dispatches"] += 1
        req.t_first_token = time.time()
        req.generated.append(tok)
        self.stats["tokens_out"] += 1
        self._tokens[slot] = tok
        self._positions[slot] = req.prompt_len
        self._maybe_finish(slot, req, tok, req.t_first_token)

    def _ensure_blocks(self, need: int = 1) -> None:
        """Map each active slot's write window (``need`` positions from its
        frontier — 1 per decode tick, k+1 per speculative tick); stall
        slots the pool cannot serve, and break an all-stalled deadlock by
        evicting the stalled request holding the most pages."""
        self._stalled = set()
        active = self.scheduler.active()
        for slot, _ in active:
            if not self.allocator.ensure_range(
                    slot, int(self._positions[slot]), need):
                self._stalled.add(slot)
        if self._stalled and len(self._stalled) == len(active):
            slot, req = max(active,
                            key=lambda sr: self.allocator.blocks_held(sr[0]))
            self._finish(slot, req, "cache_full", time.time())
            self.stats["preempted"] += 1
            self._stalled.discard(slot)
            for slot2 in sorted(self._stalled):
                if self.allocator.ensure_range(
                        slot2, int(self._positions[slot2]), need):
                    self._stalled.discard(slot2)

    def _maybe_finish(self, slot: int, req: Request, last_token: int,
                      now: float) -> None:
        reason = None
        if req.eos_id is not None and last_token == req.eos_id:
            reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            reason = "length"
        elif self._positions[slot] >= self.max_len:
            reason = "cache_full"   # no room to write the next token
        if reason is None:
            return
        self._finish(slot, req, reason, now)

    def _finish(self, slot: int, req: Request, reason: str,
                now: float) -> None:
        req.status = RequestStatus.FINISHED
        req.finish_reason = reason
        req.t_finish = now
        self.scheduler.release(slot)
        if self.paged:
            self.allocator.free_slot(slot)
        self._positions[slot] = self._park      # park: no cache writes
        self.stats["finished"] += 1
