"""Continuous-batching serving engine over the model zoo's decode caches.

The engine owns a fixed-shape cache with ``n_slots`` batch rows and runs a
tick loop:

1. **admit** — while a slot is free and requests are queued, the most
   urgent request (earliest deadline, then priority, then arrival order)
   is admitted: ONE lowered prefill program runs its whole (right-padded)
   prompt, the resulting per-slot KV / SSM state is scattered into the
   slot's cache row, and the first token is sampled from the
   last-position logits (this is also the time-to-first-token mark);
2. **decode** — one fused decode step advances EVERY active slot by one
   token; free slots ride along parked at the row length where the cache
   scatter writes nothing;
3. **evict** — requests that hit EOS, their ``max_new_tokens`` budget,
   the cache ceiling, or their deadline release their slot immediately,
   so the next tick's admission refills the batch.

All shapes are static — prompts pad to ``max_prompt_len``, the decode batch
is always ``n_slots`` wide — so the engine compiles exactly two programs
(one prefill, one decode) regardless of traffic.  Per-request compute is
batch-row-independent (each slot attends only to its own cache row), so a
request's output stream is identical to running it alone; the engine test
pins that down.

Paged mode (``paged=True``) swaps the dense per-slot ``max_len`` slabs for
a global pool of ``block_size``-token pages managed by
:class:`repro.serving.blocks.BlockAllocator`: admission is gated on free
blocks for the prompt plus one decode token, decode growth maps pages
lazily, and a slot whose next page cannot be mapped *stalls* (parks for
the tick, producing nothing) until an eviction frees pages — so the pool
can be sized for the traffic mix instead of ``n_slots * max_len`` while
greedy output streams stay identical to the dense cache.

**Preemption with recompute**: when every active slot is stalled at once
(deadlock), or a deadline demands the capacity, the victim slot's pages
are released and the request is *requeued* — its generated-so-far tokens
fold into the re-prefill context at readmission, so a greedy stream
continues bit-identically to an undisturbed run (prefill and decode agree
position-for-position; pinned by tests/test_serving_resilience.py).  A
per-request ``max_preemptions`` budget with exponential tick backoff
bounds the retries; past it the request finishes with
``finish_reason="preempted_limit"``.  The same requeue path heals
corrupt decode output (non-finite logits produce out-of-range sample
ids, which the host-side validity guard catches).

**Deadline-aware scheduling**: requests carry ``deadline_s`` / priority;
admission is earliest-deadline-first with aging (see ``scheduler.py``),
queued requests past their deadline are swept to
``finish_reason="timeout"`` without burning a prefill, active ones are
evicted on expiry, and a queued request about to miss its deadline may
preempt-with-requeue the active request with the most slack.

**Graceful degradation**: a tick-latency watchdog
(:class:`repro.dist.elastic.StragglerMonitor`) plus pool-pressure and
queue-depth signals drive a reversible ladder — shrink ``spec_k``, then
disable speculation, then bound the admission queue and shed the
lowest-priority arrivals (``finish_reason="rejected"``) — stepping back
up after sustained calm.  Every transition and shed is counted in
``stats``; ladder moves never change greedy token streams (speculation
is exact and shedding only drops whole requests).

Speculative mode (``spec_k > 0``) replaces the one-token decode tick with
draft -> verify -> accept/rollback: a cheap draft source
(:mod:`repro.spec.draft`, default the target's own truncated ACDC
cascades) proposes ``spec_k`` tokens per slot in one fused program, ONE
target verify program scores and commits them
(:func:`repro.dist.steps.make_verify_step`), and each slot advances by
its accepted length — variable per slot, shapes static via masking.
Greedy streams stay bit-identical to the non-speculative engine; see
:mod:`repro.serving` for the tick contract.

Fault injection (``fault=FaultPlan(...)``) threads a deterministic
seed-driven chaos schedule behind a no-op default into the allocator and
the tick loop; see :mod:`repro.serving.faults`.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import steps as steps_mod
from repro.dist.elastic import StragglerMonitor
from repro.obs import Observability
from repro.obs.metrics import StatsView
from repro.serving import sampler as sampler_mod
from repro.serving.blocks import BlockAllocator
from repro.serving.faults import FaultPlan
from repro.serving.request import Request, RequestStatus
from repro.serving.scheduler import Scheduler

#: ``Engine.stats`` key -> (registry metric name, kind).  Kinds:
#: ``counter`` (int-valued), ``seconds`` (float counter), ``gauge``,
#: ``derived`` (computed at read/snapshot time — never stored, so it can
#: never go stale).  The prose cross-reference lives in
#: ``repro/serving/__init__.py``; the glossary in ``repro/obs/__init__``.
STATS_METRICS = {
    "prefill_dispatches": ("serve_prefill_dispatches_total", "counter"),
    "decode_ticks": ("serve_decode_ticks_total", "counter"),
    "tokens_out": ("serve_tokens_out_total", "counter"),
    "finished": ("serve_finished_total", "counter"),
    "preempted": ("serve_preempted_total", "counter"),
    "requeued": ("serve_requeued_total", "counter"),
    "timeout": ("serve_timeout_total", "counter"),
    "rejected": ("serve_rejected_total", "counter"),
    "deadline_preempts": ("serve_deadline_preempts_total", "counter"),
    "corrupt_ticks": ("serve_corrupt_ticks_total", "counter"),
    "stalled_slot_ticks": ("serve_stalled_slot_ticks_total", "counter"),
    "degrade_level": ("serve_degrade_level", "gauge"),
    "degrade_down": ("serve_degrade_down_total", "counter"),
    "degrade_up": ("serve_degrade_up_total", "counter"),
    "prefill_s": ("serve_prefill_seconds_total", "seconds"),
    "decode_s": ("serve_decode_seconds_total", "seconds"),
    "drafted": ("serve_spec_drafted_total", "counter"),
    "accepted": ("serve_spec_accepted_total", "counter"),
    "acceptance_rate": ("serve_acceptance_rate", "derived"),
    "attn_gather_bytes": ("serve_attn_gather_bytes_total", "counter"),
    "attn_kernel_bytes": ("serve_attn_kernel_bytes_total", "counter"),
}


class Engine:
    def __init__(
        self,
        model,
        cfg,
        params,
        n_slots: int = 4,
        max_len: int = 128,
        max_prompt_len: Optional[int] = None,
        sample: str = "greedy",
        temperature: float = 1.0,
        top_k: int = 0,
        top_p: float = 0.0,
        rng: Optional[jax.Array] = None,
        paged: bool = False,
        block_size: int = 16,
        n_blocks: Optional[int] = None,
        admit_window: int = 4,
        age_limit: int = 16,
        spec_k: int = 0,
        draft=None,
        draft_depth: Optional[int] = None,
        draft_skip_layers: int = 0,
        clock: Optional[Callable[[], float]] = None,
        fault: Optional[FaultPlan] = None,
        obs: Optional[Observability] = None,
        deadline_margin_s: float = 0.05,
        queue_bound: Optional[int] = None,
        degrade_down_after: int = 3,
        degrade_up_after: int = 12,
    ):
        if model.prefill is None or model.decode_step is None:
            raise ValueError(f"family {cfg.family!r} cannot serve")
        if paged and (model.init_cache_paged is None
                      or model.decode_step_paged is None):
            raise ValueError(
                f"family {cfg.family!r} has no paged KV cache (its decode "
                "state is not length-proportional); serve it dense")
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0 (0 disables)")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_prompt_len = max_prompt_len or max_len // 2
        self.paged = paged
        self._clock = clock if clock is not None else time.time
        # duration source: wall time by default, the INJECTED clock when
        # one is supplied — a virtual-clock chaos run then produces fully
        # deterministic tick/prefill/decode timings, which is what makes
        # trace and snapshot replays byte-identical (tests/test_obs.py)
        self._timer = clock if clock is not None else time.perf_counter
        self._fault = fault
        self.deadline_margin_s = deadline_margin_s
        self.queue_bound = queue_bound if queue_bound is not None \
            else 4 * n_slots
        self._rng = jax.random.PRNGKey(0) if rng is None else rng
        # disjoint RNG streams: decode-tick keys chain through fold_in(_, 0)
        # and admission keys through fold_in(_, 1), so a tick counter can
        # never collide with a request id (bit-packing both into one fold
        # value was non-injective: tick 2**20 reused tick 0's key and
        # rid >= 2**20 collided with decode keys)
        self._rng_decode = jax.random.fold_in(self._rng, 0)
        self._rng_admit = jax.random.fold_in(self._rng, 1)

        if paged:
            self.block_size = block_size
            self.max_blocks = -(-max_len // block_size)
            # virtual per-slot row length: max_len rounded up to whole
            # pages; the engine still stops requests at max_len, the tail
            # padding just keeps the page-wise gather rectangular
            self._virtual = self.max_blocks * block_size
            if n_blocks is None:
                n_blocks = n_slots * self.max_blocks  # dense-parity pool
            min_pool = -(-(self.max_prompt_len + 1) // block_size)
            if n_blocks < min_pool:
                raise ValueError(
                    f"pool of {n_blocks} blocks cannot admit a "
                    f"max_prompt_len={self.max_prompt_len} request "
                    f"(needs {min_pool})")
            self.allocator = BlockAllocator(n_blocks, block_size, n_slots,
                                            self.max_blocks, fault=fault)
            # capacity check on ctx_len, not prompt_len: a requeued
            # request re-prefills its prompt PLUS generated-so-far tokens
            self.scheduler = Scheduler(
                n_slots,
                admit_ok=lambda r: self.allocator.can_admit(r.ctx_len),
                window=admit_window, age_limit=age_limit)
            self._park = self._virtual
            self._cache = model.init_cache_paged(cfg, n_slots, n_blocks,
                                                 block_size)
            # batch-1 dense template the admission prefill writes through
            # before the in-program page scatter
            self._slot_template = model.init_cache(cfg, 1, self._virtual)
            self._prefill = jax.jit(steps_mod.make_prefill_step(
                model, cfg, paged=True), donate_argnums=(1,))
            self._decode = jax.jit(steps_mod.make_serve_step(
                model, cfg, sample=sample, temperature=temperature,
                top_k=top_k, top_p=top_p, paged=True), donate_argnums=(1,))
            self._insert = None
        else:
            self.allocator = None
            self.scheduler = Scheduler(n_slots, age_limit=age_limit)
            self._park = max_len
            self._cache = model.init_cache(cfg, n_slots, max_len)
            # template for per-admission prefill: batch-1, same max_len slabs
            self._slot_template = model.init_cache(cfg, 1, max_len)
            # the big cache is donated through decode/insert: it is the
            # dominant serving allocation and both calls replace
            # self._cache wholesale, so XLA can update the buffers in
            # place instead of copying the whole multi-layer slab per tick
            self._prefill = jax.jit(steps_mod.make_prefill_step(model, cfg))
            self._decode = jax.jit(steps_mod.make_serve_step(
                model, cfg, sample=sample, temperature=temperature,
                top_k=top_k, top_p=top_p), donate_argnums=(1,))
            self._insert = steps_mod.make_insert_step()

        self._tokens = np.zeros((n_slots,), np.int32)
        self._positions = np.full((n_slots,), self._park, np.int32)
        self._stalled: Set[int] = set()
        self._sample = jax.jit(functools.partial(
            sampler_mod.sample, method=sample, temperature=temperature,
            top_k=top_k, top_p=top_p))
        # observability: the registry is ALWAYS live (it backs the
        # back-compat ``stats`` view); tracing / export / profiling are
        # optional surfaces, each a single None-check when off — the
        # documented noop path (see repro/obs/__init__.py).  An
        # Observability bundle must not be shared between engines: the
        # get-or-create registry would silently merge their stats.
        self.obs = obs if obs is not None else Observability.off()
        self._tracer = self.obs.tracer
        if self._tracer is not None and self._tracer.clock is None:
            self._tracer.clock = self._clock  # adopt the engine clock
        self._obs_tick = self.obs.tick_hook()
        self._prof = self.obs.prof
        self.stats = self._build_stats()
        reg = self.obs.registry
        self._h_ttft = reg.histogram(
            "serve_ttft_seconds", "submit -> first token latency")
        self._h_tpot = reg.histogram(
            "serve_tpot_seconds",
            "per-output-token decode latency: (t_finish - ttft)/(n-1)")
        self._h_tick = reg.histogram(
            "serve_tick_seconds", "engine tick wall latency")
        self.wall_clock_exceeded = False
        # preempted requests wait out an exponential backoff (in ticks)
        # before re-entering the queue: (eligible_tick, request)
        self._backoff: List[Tuple[int, Request]] = []
        self._tick_no = 0

        self.spec_k = spec_k
        self.spec_k_eff = spec_k
        self.draft = None
        if spec_k:
            vfn = model.verify_step_paged if paged else model.verify_step
            if vfn is None:
                raise ValueError(
                    f"family {cfg.family!r} has no "
                    f"{'paged ' if paged else ''}speculative verify path")
            if draft is None:
                # paper-native default: the target's own cascades truncated
                # to half depth (sections 3-4 depth result)
                from repro.spec.draft import TruncatedCascadeDraft
                depth = (draft_depth if draft_depth is not None
                         else max(1, cfg.sell_k // 2))
                draft = TruncatedCascadeDraft(cfg, params, depth=depth,
                                              skip_layers=draft_skip_layers)
            self.draft = draft
            self.draft.prepare(n_slots, self.max_len, spec_k, sample,
                               temperature, top_k, top_p)
            self._verify = jax.jit(steps_mod.make_verify_step(
                model, cfg, sample=sample, temperature=temperature,
                top_k=top_k, top_p=top_p, paged=paged, park=self._park),
                donate_argnums=(1,))

        # graceful-degradation ladder: reversible step-downs ordered
        # cheapest-first (shrinking speculation costs acceptance rate,
        # never tokens), with request shedding strictly last
        self._levels = ["full"]
        if spec_k >= 2:
            self._levels.append("spec_half")
        if spec_k >= 1:
            self._levels.append("spec_off")
        self._levels.append("shed")
        self._level = 0
        self._hot = 0
        self._calm = 0
        self.degrade_down_after = degrade_down_after
        self.degrade_up_after = degrade_up_after
        self._watchdog = StragglerMonitor(alpha=0.2, factor=3.0, warmup=3,
                                          adapt_after=5)

    # -- accounting --------------------------------------------------------

    def _build_stats(self) -> StatsView:
        """Bind every historical ``stats`` key to its registry metric
        (table: ``STATS_METRICS``).  ``acceptance_rate`` is DERIVED —
        computed from the drafted/accepted counters at read time — which
        fixes the seed's staleness bug: the stored ratio was only
        refreshed inside the spec tick while ``drafted`` grew, so a run
        degraded to ``spec_off`` kept reporting its pre-degradation
        value forever."""
        reg = self.obs.registry
        view = StatsView()
        for key, (name, kind) in STATS_METRICS.items():
            if kind == "counter":
                m = reg.counter(name)
                view.bind(key, lambda m=m: int(m.value), m.set)
            elif kind == "seconds":
                m = reg.counter(name)
                view.bind(key, lambda m=m: float(m.value), m.set)
            elif kind == "gauge":
                m = reg.gauge(name)
                view.bind(key, lambda m=m: int(m.value), m.set)
        drafted = reg.counter(STATS_METRICS["drafted"][0])
        accepted = reg.counter(STATS_METRICS["accepted"][0])
        rate = reg.derived_gauge(
            STATS_METRICS["acceptance_rate"][0],
            lambda: (accepted.value / drafted.value) if drafted.value
            else 0.0,
            "accepted/drafted, computed at snapshot time (never stale)")
        view.bind("acceptance_rate", rate)
        return view

    @property
    def cache_bytes(self) -> int:
        """Bytes held by the decode cache (the dominant serving
        allocation): dense slabs or the paged pool, whichever is live —
        plus the draft's dense slot cache in speculative mode, so the
        self-draft's memory cost stays visible next to a paged pool."""
        total = sum(leaf.nbytes for leaf in jax.tree.leaves(self._cache))
        if self.draft is not None:
            total += self.draft.cache_bytes
        return total

    def _attn_bytes_tick(self, pos: np.ndarray) -> None:
        """Analytic attention K/V traffic for one paged decode/verify tick,
        accumulated into ``stats`` (model, not a measurement):

        * ``attn_gather_bytes`` — what the block-table *gather* path reads:
          every K/V page pool is materialised as a ``(n_slots, virtual,
          Hkv, Dh)`` view, so each layer costs ``n_slots * virtual`` tokens
          regardless of how full any row is (O(max_blocks * block_size)
          per slot).
        * ``attn_kernel_bytes`` — what the fused streaming kernel reads:
          per live row, only the mapped prefix ``ceil(pos / block_size)``
          pages; parked and stalled rows cost nothing.  Window narrowing
          and the chunk-granularity round-up are ignored, so this is a
          slight over-estimate for sliding-window layers.

        Both counters advance every paged tick whichever path actually
        ran, so fused and gather runs of the same trace report identical
        numbers and the ratio is a pure memory-model statement.
        """
        gather = kernel = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._cache)[0]:
            if not any("pages" in str(k) for k in path):
                continue
            n_layers, bs = leaf.shape[0], leaf.shape[2]
            tok_bytes = int(np.prod(leaf.shape[3:])) * leaf.dtype.itemsize
            gather += n_layers * self.n_slots * self._virtual * tok_bytes
            for p in pos:
                p = int(p)
                if p < self._virtual:
                    kernel += n_layers * (-(-p // bs) * bs) * tok_bytes
        self.stats["attn_gather_bytes"] += gather
        self.stats["attn_kernel_bytes"] += kernel

    def _decode_rng(self, tick: int) -> jax.Array:
        return jax.random.fold_in(self._rng_decode, tick)

    def _admit_rng(self, rid: int) -> jax.Array:
        return jax.random.fold_in(self._rng_admit, rid)

    # -- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.prompt_len < 1:
            raise ValueError(f"request {request.rid}: empty prompt")
        if request.prompt_len > self.max_prompt_len:
            raise ValueError(
                f"request {request.rid}: prompt {request.prompt_len} > "
                f"max_prompt_len {self.max_prompt_len}")
        if request.deadline_s is not None and request.deadline_s <= 0:
            raise ValueError(
                f"request {request.rid}: deadline_s must be positive")
        if self.cfg.family == "encdec" and request.frontend_embeds is None:
            # without frames the cross-KV stays all-zero: the request would
            # "succeed" while conditioning on a null encoder
            raise ValueError(
                f"request {request.rid}: encdec family needs "
                f"frontend_embeds")
        now = self._clock()
        request.t_submit = now
        tr = self._tracer
        if tr is not None:
            tr.req_phase(request.rid, "queued")
        # degradation ladder, last rung: the admission queue is bounded
        # and the lowest-priority request (newest on ties) is shed
        if (self._levels[self._level] == "shed"
                and len(self.scheduler.queue) >= self.queue_bound):
            victim = min(
                [request] + list(self.scheduler.queue),
                key=lambda r: (r.priority,
                               -(r.seq if r.seq is not None else 1 << 62)))
            if victim is not request:
                self.scheduler.queue.remove(victim)
            victim.status = RequestStatus.FINISHED
            victim.finish_reason = "rejected"
            victim.t_finish = now
            self.stats["rejected"] += 1
            self.stats["finished"] += 1
            if tr is not None:
                tr.req_terminal(victim.rid, "rejected",
                                shed_for=request.rid)
            if victim is request:
                return
        self.scheduler.submit(request)

    # -- tick loop --------------------------------------------------------

    def _release_backoff(self) -> None:
        """Re-enter preempted requests whose backoff has elapsed."""
        if not self._backoff:
            return
        ready = [r for t, r in self._backoff if t <= self._tick_no]
        self._backoff = [(t, r) for t, r in self._backoff
                         if t > self._tick_no]
        for req in ready:
            self.scheduler.submit(req)
            if self._tracer is not None:
                self._tracer.req_phase(req.rid, "queued", requeue=True)

    def _admit_pass(self) -> None:
        if self.paged:
            # one at a time: each admission's block allocation must be
            # visible to the next can_admit capacity check
            while True:
                admitted = self.scheduler.admit(limit=1)
                if not admitted:
                    break
                self._admit(*admitted[0])
        else:
            for slot, req in self.scheduler.admit():
                self._admit(slot, req)

    def _admit_and_map(self) -> None:
        """Backoff release + admission + deadline preemption + (paged)
        mapping of this tick's write window."""
        self._release_backoff()
        self._admit_pass()
        if self._deadline_preempt(self._clock()):
            self._admit_pass()
        if self.paged:
            self._ensure_blocks(need=(self.spec_k_eff or 0) + 1)

    def tick(self) -> int:
        """Deadline sweep + admit + one fused decode step; returns
        #active slots advanced."""
        tick_no = self._tick_no
        self._tick_no += 1
        if self._obs_tick is not None:    # exporter cadence + profile
            self._obs_tick(tick_no)       # window; None when neither set
        self._expire_deadlines(self._clock())
        t0 = self._timer()
        if self.spec_k_eff:
            n = self._tick_spec(tick_no)
        else:
            n = self._tick_decode(tick_no)
        dt = self._timer() - t0
        if self._fault is not None:
            extra = self._fault.extra_tick_s(tick_no)
            if extra and self._tracer is not None:
                self._tracer.instant("engine", "fault:slow_tick",
                                     tick=tick_no, extra_s=extra)
            dt += extra
        self._h_tick.observe(dt)
        self._observe_pressure(dt, tick_no)
        return n

    def _tick_decode(self, tick_no: int) -> int:
        self._admit_and_map()
        active = self.scheduler.active()
        if active:
            rng = self._decode_rng(self.stats["decode_ticks"])
            t0 = self._timer()
            with self._prof.annotate("decode"):
                if self.paged:
                    pos = self._positions.copy()
                    for slot in self._stalled:
                        pos[slot] = self._park  # no write/token this tick
                    self._attn_bytes_tick(pos)
                    tok, self._cache = self._decode(
                        self.params, self._cache, jnp.asarray(self._tokens),
                        jnp.asarray(pos), jnp.asarray(self.allocator.table),
                        rng)
                else:
                    tok, self._cache = self._decode(
                        self.params, self._cache, jnp.asarray(self._tokens),
                        jnp.asarray(self._positions), rng)
                tok_np = np.asarray(tok)
            self.stats["decode_s"] += self._timer() - t0
            self.stats["decode_ticks"] += 1
            self.stats["stalled_slot_ticks"] += len(self._stalled)
            if self._fault is not None and self._fault.logits_corrupt(
                    tick_no):
                # simulated NaN/inf logits: every sampled id is garbage
                tok_np = np.full_like(tok_np, -1)
                self.stats["corrupt_ticks"] += 1
                if self._tracer is not None:
                    self._tracer.instant("engine", "fault:corrupt_logits",
                                         tick=tick_no)
            now = self._clock()
            for slot, req in active:
                if slot in self._stalled:
                    continue  # parked this tick: its sampled token is junk
                t = int(tok_np[slot])
                if not 0 <= t < self.cfg.vocab_size:
                    # corrupt decode output: heal by recompute — requeue
                    # and re-prefill rather than commit a garbage token
                    self._heal_or_kill(slot, req, now)
                    continue
                req.generated.append(t)
                self.stats["tokens_out"] += 1
                self._positions[slot] += 1
                self._tokens[slot] = t
                self._maybe_finish(slot, req, t, now)
        return len(active)

    def _tick_spec(self, tick_no: int) -> int:
        """One speculative tick: draft k, verify once, advance each slot
        by its accepted length, roll back the rest."""
        k = self.spec_k_eff
        self._admit_and_map()
        active = self.scheduler.active()
        if not active:
            return 0
        tick_rng = self._decode_rng(self.stats["decode_ticks"])
        draft_rng = jax.random.fold_in(tick_rng, 0)
        verify_rng = jax.random.fold_in(tick_rng, 1)
        pos = self._positions.copy()
        for slot in self._stalled:
            pos[slot] = self._park  # no writes, no tokens this tick
        if self.paged:
            self._attn_bytes_tick(pos)

        t0 = self._timer()
        with self._prof.annotate("draft"):
            drafts, draft_logits = self.draft.propose(self._tokens, pos,
                                                      draft_rng)
        tok_mat = np.concatenate([self._tokens[:, None], drafts],
                                 axis=1).astype(np.int32)
        with self._prof.annotate("verify"):
            if self.paged:
                acc, out, self._cache = self._verify(
                    self.params, self._cache, jnp.asarray(tok_mat),
                    jnp.asarray(drafts), draft_logits, jnp.asarray(pos),
                    jnp.asarray(self.allocator.table), verify_rng)
            else:
                acc, out, self._cache = self._verify(
                    self.params, self._cache, jnp.asarray(tok_mat),
                    jnp.asarray(drafts), draft_logits, jnp.asarray(pos),
                    verify_rng)
            acc_np = np.asarray(acc)
            out_np = np.asarray(out)
        self.stats["decode_s"] += self._timer() - t0
        self.stats["decode_ticks"] += 1
        self.stats["stalled_slot_ticks"] += len(self._stalled)
        corrupt = (self._fault is not None
                   and self._fault.logits_corrupt(tick_no))
        if corrupt:
            self.stats["corrupt_ticks"] += 1
            if self._tracer is not None:
                self._tracer.instant("engine", "fault:corrupt_logits",
                                     tick=tick_no)

        now = self._clock()
        n_adv = np.zeros((self.n_slots,), np.int32)
        for slot, req in active:
            if slot in self._stalled:
                continue
            if corrupt:
                # simulated NaN/inf verify logits: commit nothing for the
                # slot, heal by recompute (requeue -> re-prefill)
                self._heal_or_kill(slot, req, now)
                continue
            n = int(acc_np[slot])
            self.stats["drafted"] += k
            self.stats["accepted"] += n
            # commit the accepted drafts plus the correction/bonus token,
            # applying the per-token stop rules in stream order so EOS /
            # budget / ceiling cut the stream exactly where the
            # non-speculative engine would
            for i in range(n + 1):
                t = int(out_np[slot, i])
                if not 0 <= t < self.cfg.vocab_size:
                    self._heal_or_kill(slot, req, now)
                    break
                req.generated.append(t)
                self.stats["tokens_out"] += 1
                self._positions[slot] += 1
                self._tokens[slot] = t
                n_adv[slot] += 1
                self._maybe_finish(slot, req, t, now)
                if req.done:
                    break
        # (acceptance_rate needs no update here: it is a derived gauge
        # over the drafted/accepted counters, computed at read time)
        self.draft.commit(n_adv)
        if self.paged:
            # rollback: return verify-window pages beyond each surviving
            # slot's committed frontier (finished slots already freed all,
            # preempted/healed slots were fully released by the requeue).
            # +1 keeps the page the NEXT tick writes first: releasing it on
            # a page-boundary frontier would let the admission pass snatch
            # it back and spuriously stall (or even preempt) this slot.
            for slot, req in active:
                if (req.status is RequestStatus.ACTIVE
                        and slot not in self._stalled):
                    self.allocator.trim_slot(
                        slot, int(self._positions[slot]) + 1)
        return len(active)

    @property
    def has_work(self) -> bool:
        """Queued, active, or backoff-parked work remains."""
        return self.scheduler.has_work or bool(self._backoff)

    def run(self, requests: Sequence[Request],
            max_ticks: Optional[int] = None,
            wall_clock_limit_s: Optional[float] = None) -> List[Request]:
        """Submit everything, tick until drained, return the requests.

        ``wall_clock_limit_s`` bounds the real time spent in the loop: a
        hung or livelocked tick loop (e.g. a fault plan that never lets a
        page map) exits with partial results — ``wall_clock_exceeded`` set
        and unfinished requests left in their current state — instead of
        spinning forever.  ``max_ticks`` still bounds the tick count
        exactly and raises, as a logic-error (not overload) guard.
        """
        for r in requests:
            self.submit(r)
        ticks = 0
        t0 = time.perf_counter()
        while self.has_work:
            if (wall_clock_limit_s is not None
                    and time.perf_counter() - t0 > wall_clock_limit_s):
                self.wall_clock_exceeded = True
                break
            if max_ticks is not None and ticks >= max_ticks:
                raise RuntimeError(f"engine not drained after {ticks} ticks")
            self.tick()
            ticks += 1
        return list(requests)

    # -- deadlines / preemption -------------------------------------------

    def _expire_deadlines(self, now: float) -> None:
        """Sweep queued and active requests past their deadline to
        ``finish_reason="timeout"``."""
        for req in self.scheduler.expire(now):
            req.status = RequestStatus.FINISHED
            req.finish_reason = "timeout"
            req.t_finish = now
            self.stats["timeout"] += 1
            self.stats["finished"] += 1
            if self._tracer is not None:
                self._tracer.req_terminal(req.rid, "timeout", queued=True)
        for slot, req in self.scheduler.active():
            if now >= req.deadline_abs():
                self.stats["timeout"] += 1
                self._finish(slot, req, "timeout", now)

    def _can_requeue(self, req: Request) -> bool:
        """May this active request be preempted-with-requeue?  Needs
        budget left and a context short enough to re-prefill (the prompt
        plus generated-so-far must fit the prefill window)."""
        return (req.n_preemptions < req.max_preemptions
                and req.ctx_len <= self.max_prompt_len)

    def _evict_reason(self, req: Request) -> str:
        return ("preempted_limit"
                if req.n_preemptions >= req.max_preemptions
                else "cache_full")

    def _preempt(self, slot: int, req: Request) -> None:
        """Preempt-and-requeue with recompute: release the slot (and its
        pages), park the row, and send the request back to the queue with
        exponential tick backoff.  Its generated-so-far tokens stay on the
        request and fold into the re-prefill context at readmission, so a
        greedy stream continues bit-identically."""
        req.n_preemptions += 1
        self.scheduler.release(slot)
        if self.paged:
            self.allocator.free_slot(slot)
        self._positions[slot] = self._park      # park: no cache writes
        self._stalled.discard(slot)
        req.status = RequestStatus.QUEUED
        self.stats["preempted"] += 1
        self.stats["requeued"] += 1
        backoff = 1 << min(req.n_preemptions - 1, 6)
        self._backoff.append((self._tick_no + backoff, req))
        if self._tracer is not None:
            self._tracer.req_instant(req.rid, "preempt", slot=slot,
                                     n_preemptions=req.n_preemptions)
            self._tracer.req_phase(req.rid, "backoff", ticks=backoff)

    def preempt(self, slot: int) -> None:
        """Public preempt-and-requeue of the request in ``slot`` — the
        building block a multi-replica front door's drain-and-redistribute
        uses, and the deterministic hook the resilience tests drive."""
        req = self.scheduler.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is free")
        if not self._can_requeue(req):
            raise ValueError(
                f"request {req.rid} cannot requeue (preemptions "
                f"{req.n_preemptions}/{req.max_preemptions}, ctx "
                f"{req.ctx_len} vs max_prompt_len {self.max_prompt_len})")
        self._preempt(slot, req)

    def _heal_or_kill(self, slot: int, req: Request, now: float) -> None:
        """Corrupt decode output for this slot: requeue-with-recompute if
        the budget allows, terminal eviction otherwise."""
        if self._can_requeue(req):
            self._preempt(slot, req)
        else:
            self.stats["preempted"] += 1
            self._finish(slot, req, self._evict_reason(req), now)

    def _deadline_preempt(self, now: float) -> bool:
        """A queued request about to miss its deadline may evict-with-
        requeue the active request with the most slack.  At most one
        preemption per tick; the victim must itself be requeueable and
        strictly less urgent than the starving request."""
        starving = self.scheduler.most_urgent()
        if starving is None or starving.deadline_s is None:
            return False
        slack = starving.slack(now)
        if slack > self.deadline_margin_s:
            return False
        cands = [(s, r) for s, r in self.scheduler.active()
                 if self._can_requeue(r) and r.slack(now) > slack]
        if not cands:
            return False
        slot, req = max(
            cands,
            key=lambda sr: (sr[1].slack(now), -sr[1].priority,
                            self.allocator.blocks_held(sr[0])
                            if self.paged else 0))
        self.stats["deadline_preempts"] += 1
        if self._tracer is not None:
            self._tracer.instant("engine", "deadline_preempt",
                                 victim=req.rid, starving=starving.rid)
        self._preempt(slot, req)
        return True

    # -- degradation ladder ------------------------------------------------

    @property
    def degrade_level(self) -> str:
        """Current ladder rung name (``full`` when healthy)."""
        return self._levels[self._level]

    def _observe_pressure(self, dt: float, tick_no: int) -> None:
        """Feed the tick-latency watchdog and pool/queue pressure signals;
        step the ladder down after ``degrade_down_after`` consecutive hot
        ticks, back up after ``degrade_up_after`` consecutive calm ones."""
        straggler = self._watchdog.observe(tick_no, dt)
        if straggler and self._tracer is not None:
            self._tracer.instant("engine", "straggler", tick=tick_no,
                                 dt_s=dt)
        pool_dry = (self.paged and bool(self._stalled)
                    and self.allocator.n_free == 0)
        queue_over = len(self.scheduler.queue) > self.queue_bound
        if straggler or pool_dry or queue_over:
            self._hot += 1
            self._calm = 0
            if (self._hot >= self.degrade_down_after
                    and self._level < len(self._levels) - 1):
                self._set_level(self._level + 1)
                self._hot = 0
        else:
            self._calm += 1
            self._hot = 0
            if self._calm >= self.degrade_up_after and self._level > 0:
                self._set_level(self._level - 1)
                self._calm = 0

    def _set_level(self, level: int) -> None:
        """Apply one reversible ladder transition.  Ordering guarantee:
        levels only ever change speculation depth (token streams are
        invariant — greedy speculation is exact at any k, including 0)
        or gate NEW admissions (shedding); tokens already streaming are
        never altered by a transition."""
        if level > self._level:
            self.stats["degrade_down"] += 1
        else:
            self.stats["degrade_up"] += 1
        if self._tracer is not None:
            self._tracer.instant(
                "engine", "ladder",
                src=self._levels[self._level], dst=self._levels[level],
                direction="down" if level > self._level else "up")
        self._level = level
        self.stats["degrade_level"] = level
        name = self._levels[level]
        k_eff = {"full": self.spec_k,
                 "spec_half": max(1, self.spec_k // 2),
                 "spec_off": 0,
                 "shed": 0}[name]
        if self.spec_k and k_eff != self.spec_k_eff:
            self.spec_k_eff = k_eff
            if k_eff and self.draft is not None:
                self.draft.set_k(k_eff)
        # the per-tick cost legitimately changed with the level: re-seed
        # the watchdog baseline instead of flagging every healthy tick
        self._watchdog.reset()

    # -- internals --------------------------------------------------------

    def _admit(self, slot: int, req: Request) -> None:
        # re-prefill context: the prompt plus (after a preemption) every
        # token generated so far — recompute makes the requeue transparent
        ctx = list(req.prompt) + [int(t) for t in req.generated]
        clen = len(ctx)
        p = self.max_prompt_len
        toks = np.zeros((1, p), np.int32)
        toks[0, :clen] = np.asarray(ctx, np.int32)
        lengths = jnp.asarray([clen], jnp.int32)
        fe = getattr(req, "frontend_embeds", None)
        if self._tracer is not None:
            self._tracer.req_phase(req.rid, "prefill", slot=slot,
                                   ctx_len=clen)
        t0 = self._timer()
        with self._prof.annotate("prefill"):
            if self.paged:
                self.allocator.alloc_slot(slot, clen)
                last_logits, self._cache = self._prefill(
                    self.params, self._cache, self._slot_template,
                    jnp.asarray(toks), lengths,
                    jnp.asarray(self.allocator.phys_row(slot)),
                    jnp.int32(slot), fe)
            else:
                last_logits, slot_cache = self._prefill(
                    self.params, self._slot_template, jnp.asarray(toks),
                    lengths, fe)
                self._cache = self._insert(self._cache, slot_cache,
                                           jnp.int32(slot))
            tok = int(self._sample(self._admit_rng(req.rid), last_logits)[0])
            if self.draft is not None:
                # the draft mirrors the slot layout: its own (cheap)
                # prefill fills its cache row so drafting starts from the
                # same prompt
                self.draft.prefill(slot, jnp.asarray(toks), lengths, fe)
        self.stats["prefill_s"] += self._timer() - t0
        self.stats["prefill_dispatches"] += 1
        now = self._clock()
        if req.t_first_token is None:       # readmissions keep the mark
            req.t_first_token = now
            if req.t_submit is not None:
                self._h_ttft.observe(now - req.t_submit)
        if self._tracer is not None:
            self._tracer.req_phase(req.rid, "decode", slot=slot)
        req.generated.append(tok)
        self.stats["tokens_out"] += 1
        self._tokens[slot] = tok
        self._positions[slot] = clen
        self._maybe_finish(slot, req, tok, now)

    def _ensure_blocks(self, need: int = 1) -> None:
        """Map each active slot's write window (``need`` positions from its
        frontier — 1 per decode tick, k+1 per speculative tick); stall
        slots the pool cannot serve, and break an all-stalled deadlock by
        preempting-with-requeue the lowest-priority stalled request
        holding the most pages (terminal eviction only when its requeue
        budget or re-prefill window is exhausted)."""
        self._stalled = set()
        active = self.scheduler.active()
        for slot, _ in active:
            forced = (self._fault is not None
                      and self._fault.spurious_stall(slot))
            if forced and self._tracer is not None:
                self._tracer.instant("engine", "fault:spurious_stall",
                                     slot=slot)
            if forced or not self.allocator.ensure_range(
                    slot, int(self._positions[slot]), need):
                self._stalled.add(slot)
        if self._stalled and len(self._stalled) == len(active):
            stalled = [(s, r) for s, r in active if s in self._stalled]
            requeueable = [(s, r) for s, r in stalled
                           if self._can_requeue(r)]
            pool = requeueable or stalled
            slot, req = max(pool, key=lambda sr: (
                -sr[1].priority, self.allocator.blocks_held(sr[0])))
            if requeueable:
                self._preempt(slot, req)
            else:
                self.stats["preempted"] += 1
                self._finish(slot, req, self._evict_reason(req),
                             self._clock())
                self._stalled.discard(slot)
            for slot2 in sorted(self._stalled):
                if self.allocator.ensure_range(
                        slot2, int(self._positions[slot2]), need):
                    self._stalled.discard(slot2)

    def _maybe_finish(self, slot: int, req: Request, last_token: int,
                      now: float) -> None:
        reason = None
        if req.eos_id is not None and last_token == req.eos_id:
            reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            reason = "length"
        elif self._positions[slot] >= self.max_len:
            reason = "cache_full"   # no room to write the next token
        if reason is None:
            return
        self._finish(slot, req, reason, now)

    def _finish(self, slot: int, req: Request, reason: str,
                now: float) -> None:
        req.status = RequestStatus.FINISHED
        req.finish_reason = reason
        req.t_finish = now
        self.scheduler.release(slot)
        if self.paged:
            self.allocator.free_slot(slot)
        self._positions[slot] = self._park      # park: no cache writes
        self.stats["finished"] += 1
        n = len(req.generated)
        if req.t_first_token is not None and n > 1:
            self._h_tpot.observe(
                max(now - req.t_first_token, 0.0) / (n - 1))
        if self._tracer is not None:
            self._tracer.req_terminal(req.rid, reason, tokens=n)
