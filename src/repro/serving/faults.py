"""Deterministic fault injection for the serving engine.

Why: the engine's overload machinery — preempt-and-requeue with recompute,
deadline timeouts, the graceful-degradation ladder, stall/deadlock
breaking — only earns trust if it is *exercised*, and real faults (a dry
page pool mid-burst, a NaN tick from a flaky accelerator, a straggling
host) are rare and unreproducible in CI.  A :class:`FaultPlan` is a
seed-driven schedule of synthetic faults threaded behind a no-op default
into the allocator and the tick loop, so a chaos test can replay the exact
same fault sequence every run and assert the recovery invariants: every
request reaches a terminal state, greedy streams of requests that finish
normally are bit-identical to a fault-free run (recompute heals
preemptions and corrupt ticks), and ``BlockAllocator.audit()`` comes back
leak-free.

Fault surfaces (all off by default — a ``None`` plan costs nothing):

* **allocator returns no page** (``p_alloc_fail``) — ``can_admit`` /
  ``ensure_range`` report a dry pool even when pages are free, forcing
  admission gating, decode stalls, and the all-stalled preempt-requeue
  path.  Injected *before* any page is mapped, so the allocator's own
  invariants hold and ``audit()`` must stay clean through any plan.
* **NaN/inf logits on a chosen tick** (``nan_ticks`` / ``p_nan``) — the
  engine treats the tick's sampled tokens as garbage (the host-side
  validity guard fires) and heals the affected slots by preempt-requeue:
  re-prefill recomputes clean state, so greedy streams are unchanged.
* **simulated slow ticks** (``slow_ticks`` / ``p_slow`` +
  ``slow_extra_s``) — extra seconds added to the tick duration the
  degradation watchdog observes (simulated, not slept: chaos runs stay
  CPU-fast), driving ladder step-downs without real stragglers.
* **spurious stalls** (``p_spurious_stall``) — an active slot is parked
  for the tick as if its next page could not be mapped, exercising the
  stall bookkeeping off the genuinely-dry-pool path.

Determinism: each fault surface draws from its own seeded
``numpy.random.RandomState`` stream (derived from ``seed``), so one
surface's draw count never shifts another's, and two engines running the
same workload against plans built with the same parameters see the same
faults at the same decision points.  ``injected`` counts what actually
fired, for test assertions and the overload bench report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class FaultPlan:
    """Seed-driven synthetic fault schedule (see module docstring).

    Probabilities are per *decision point*: ``p_alloc_fail`` per allocator
    capacity/mapping call, ``p_spurious_stall`` per (active slot, tick),
    ``p_nan`` / ``p_slow`` per tick.  ``nan_ticks`` / ``slow_ticks`` name
    explicit tick indices on top of the random draws.
    """

    seed: int = 0
    p_alloc_fail: float = 0.0
    p_spurious_stall: float = 0.0
    p_nan: float = 0.0
    nan_ticks: Tuple[int, ...] = ()
    p_slow: float = 0.0
    slow_ticks: Tuple[int, ...] = ()
    slow_extra_s: float = 0.0

    def __post_init__(self):
        # one independent stream per fault surface: a surface's draw count
        # never shifts another surface's sequence, so plans replay exactly
        self._rs_alloc = np.random.RandomState(self.seed)
        self._rs_stall = np.random.RandomState(self.seed + 1)
        self._rs_nan = np.random.RandomState(self.seed + 2)
        self._rs_slow = np.random.RandomState(self.seed + 3)
        self.injected: Dict[str, int] = {
            "alloc_fail": 0, "spurious_stall": 0, "nan": 0, "slow": 0}

    # -- fault surfaces ----------------------------------------------------

    def alloc_fail(self) -> bool:
        """One allocator capacity/mapping decision: deny the page?"""
        if self.p_alloc_fail <= 0.0:
            return False
        hit = bool(self._rs_alloc.rand() < self.p_alloc_fail)
        if hit:
            self.injected["alloc_fail"] += 1
        return hit

    def spurious_stall(self, slot: int) -> bool:
        """Park this active slot for the tick as if its page map failed?"""
        if self.p_spurious_stall <= 0.0:
            return False
        hit = bool(self._rs_stall.rand() < self.p_spurious_stall)
        if hit:
            self.injected["spurious_stall"] += 1
        return hit

    def logits_corrupt(self, tick: int) -> bool:
        """Non-finite logits this tick (sampled tokens are garbage)?"""
        hit = tick in self.nan_ticks
        if not hit and self.p_nan > 0.0:
            hit = bool(self._rs_nan.rand() < self.p_nan)
        if hit:
            self.injected["nan"] += 1
        return hit

    def extra_tick_s(self, tick: int) -> float:
        """Extra seconds the watchdog should see for this tick (simulated
        straggler — nothing actually sleeps)."""
        hit = tick in self.slow_ticks
        if not hit and self.p_slow > 0.0:
            hit = bool(self._rs_slow.rand() < self.p_slow)
        if not hit:
            return 0.0
        self.injected["slow"] += 1
        return self.slow_extra_s
