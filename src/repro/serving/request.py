"""Request lifecycle for the continuous-batching engine.

A request is QUEUED on submit, ACTIVE while it owns a batch slot (from the
prefill admission until its stop condition), and FINISHED once it hit EOS
(``finish_reason="eos"``), generated ``max_new_tokens``
(``finish_reason="length"``), or ran into the cache ceiling
(``finish_reason="cache_full"``).  The engine mutates ``generated`` /
``status`` in place; everything else is caller-owned input.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence

import numpy as np


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    ACTIVE = "active"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]                 # token ids, ragged lengths ok
    max_new_tokens: int = 16
    eos_id: Optional[int] = None          # None: never stops on a token
    # (1, F, D) modality-frontend embeddings for encdec/vision families
    frontend_embeds: Optional[object] = None

    # engine-managed fields
    status: RequestStatus = RequestStatus.QUEUED
    generated: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    slot: Optional[int] = None
    # wall-clock marks for time-to-first-token / latency accounting
    t_submit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.status is RequestStatus.FINISHED


def make_ragged_requests(vocab_size: int, n: int, max_prompt_len: int,
                         max_new_tokens: int, seed: int = 0,
                         vary_budget: bool = False) -> List[Request]:
    """Deterministic ragged-length synthetic request stream.

    Shared by the serve launcher and bench_serve so A/B runs and the
    benchmark exercise the same workload.  Prompt lengths draw uniformly
    from [max_prompt_len/4, max_prompt_len]; ``vary_budget`` also draws
    ``max_new_tokens`` from [max/2, max].
    """
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        plen = int(rs.randint(max(max_prompt_len // 4, 1),
                              max_prompt_len + 1))
        budget = max_new_tokens
        if vary_budget:
            budget = int(rs.randint(max(max_new_tokens // 2, 1),
                                    max_new_tokens + 1))
        out.append(Request(
            rid=i, prompt=rs.randint(0, vocab_size, size=plen).tolist(),
            max_new_tokens=budget))
    return out
