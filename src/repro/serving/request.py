"""Request lifecycle for the continuous-batching engine.

A request is QUEUED on submit, ACTIVE while it owns a batch slot (from the
prefill admission until its stop condition), and FINISHED once it reaches
a terminal state.  A preempted request moves ACTIVE -> QUEUED (its pages
are freed, its generated-so-far tokens stay on the request) and is later
readmitted with those tokens folded into the re-prefill context, so the
greedy stream continues bit-identically.  ``finish_reason`` values:

* ``"eos"`` — generated the request's ``eos_id``;
* ``"length"`` — generated ``max_new_tokens``;
* ``"cache_full"`` — hit the per-slot ``max_len`` cache ceiling (or, as a
  last resort, was evicted from an all-stalled pool while too long to
  re-prefill);
* ``"timeout"`` — passed ``t_submit + deadline_s`` (queued or active);
* ``"preempted_limit"`` — exhausted its ``max_preemptions`` requeue
  budget;
* ``"rejected"`` — shed at submission by the engine's degradation ladder
  (queue bounded under overload; lowest priority goes first).

Scheduling inputs: ``deadline_s`` is a latency budget in seconds from
submission (``None`` = no deadline); admission is earliest-deadline-first
over the queue.  ``priority`` breaks ties, picks preemption victims, and
orders load shedding (higher = more important; default 0).
``max_preemptions`` bounds how many times the request may be preempted
and requeued before it is terminally evicted.

The engine mutates ``generated`` / ``status`` / the ``t_*`` marks and the
preemption bookkeeping in place; everything above the engine-managed
divider is caller-owned input.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence, Tuple

import numpy as np


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    ACTIVE = "active"
    FINISHED = "finished"


class FinishReason:
    """The closed set of terminal ``finish_reason`` values."""

    EOS = "eos"
    LENGTH = "length"
    CACHE_FULL = "cache_full"
    TIMEOUT = "timeout"
    PREEMPTED_LIMIT = "preempted_limit"
    REJECTED = "rejected"
    ALL = (EOS, LENGTH, CACHE_FULL, TIMEOUT, PREEMPTED_LIMIT, REJECTED)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]                 # token ids, ragged lengths ok
    max_new_tokens: int = 16
    eos_id: Optional[int] = None          # None: never stops on a token
    # (1, F, D) modality-frontend embeddings for encdec/vision families
    frontend_embeds: Optional[object] = None
    deadline_s: Optional[float] = None    # latency budget from t_submit
    priority: int = 0                     # higher = more important
    max_preemptions: int = 4              # requeue budget before eviction

    # engine-managed fields
    status: RequestStatus = RequestStatus.QUEUED
    generated: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    slot: Optional[int] = None
    n_preemptions: int = 0
    # scheduler bookkeeping: arrival order (stable across requeues, so a
    # preempted request keeps its seniority) and aged-head skip count
    seq: Optional[int] = None
    sched_skips: int = 0
    # wall-clock marks for time-to-first-token / latency accounting
    t_submit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def ctx_len(self) -> int:
        """Tokens a (re-)prefill must ingest: the prompt plus everything
        generated so far (non-empty only after a preemption)."""
        return len(self.prompt) + len(self.generated)

    @property
    def done(self) -> bool:
        return self.status is RequestStatus.FINISHED

    def deadline_abs(self) -> float:
        """Absolute deadline in wall seconds (inf when none is set or the
        request has not been submitted yet)."""
        if self.deadline_s is None or self.t_submit is None:
            return float("inf")
        return self.t_submit + self.deadline_s

    def slack(self, now: float) -> float:
        return self.deadline_abs() - now


def make_ragged_requests(vocab_size: int, n: int, max_prompt_len: int,
                         max_new_tokens: int, seed: int = 0,
                         vary_budget: bool = False,
                         deadline_range: Optional[Tuple[float, float]] = None,
                         deadline_frac: float = 0.5,
                         n_priorities: int = 1) -> List[Request]:
    """Deterministic ragged-length synthetic request stream.

    Shared by the serve launcher and bench_serve so A/B runs and the
    benchmark exercise the same workload.  Prompt lengths draw uniformly
    from [max_prompt_len/4, max_prompt_len]; ``vary_budget`` also draws
    ``max_new_tokens`` from [max/2, max].  ``deadline_range=(lo, hi)``
    gives a uniform ``deadline_s`` to a ``deadline_frac`` fraction of
    requests, and ``n_priorities > 1`` draws ``priority`` uniformly from
    ``[0, n_priorities)`` — the overload bench's SLO mix.
    """
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        plen = int(rs.randint(max(max_prompt_len // 4, 1),
                              max_prompt_len + 1))
        budget = max_new_tokens
        if vary_budget:
            budget = int(rs.randint(max(max_new_tokens // 2, 1),
                                    max_new_tokens + 1))
        deadline = None
        if deadline_range is not None and rs.rand() < deadline_frac:
            lo, hi = deadline_range
            deadline = float(lo + (hi - lo) * rs.rand())
        prio = int(rs.randint(0, n_priorities)) if n_priorities > 1 else 0
        out.append(Request(
            rid=i, prompt=rs.randint(0, vocab_size, size=plen).tolist(),
            max_new_tokens=budget, deadline_s=deadline, priority=prio))
    return out
