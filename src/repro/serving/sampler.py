"""Token sampling: greedy / temperature with top-k and top-p (nucleus)
filtering.

Shared by :func:`repro.dist.steps.make_serve_step` (the fused decode step
samples on-device so only int32 token ids leave the accelerator) and the
continuous-batching engine's prefill admissions.  Filters follow the usual
order: temperature scaling first, then top-k, then top-p — top-k is
temperature-invariant (monotonic scaling preserves rank) but the nucleus
set is not, so the order is observable and pinned by tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30  # large-but-finite: keeps all-masked rows NaN-free


def apply_top_k(logits: jax.Array, k: int) -> jax.Array:
    """Mask everything below the k-th largest logit.  ``k <= 0`` disables.

    Ties with the k-th value are kept (the kept set can exceed ``k`` only
    when logits are exactly equal — the standard tie-break-free contract).
    """
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < kth, _NEG_INF, logits)


def apply_top_p(logits: jax.Array, p: float) -> jax.Array:
    """Nucleus filtering: keep the smallest prefix of the probability-sorted
    vocab whose cumulative mass reaches ``p``; mask the rest.

    The top-1 token is always kept (its *preceding* mass is 0 < p), so the
    result is never fully masked.  ``p <= 0`` or ``p >= 1`` disables.
    """
    if p <= 0.0 or p >= 1.0:
        return logits
    sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    probs = jax.nn.softmax(sorted_desc.astype(jnp.float32), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p          # mass strictly before this token
    thresh = jnp.min(jnp.where(keep, sorted_desc, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits < thresh, _NEG_INF, logits)


def sample(
    rng: jax.Array,
    logits: jax.Array,               # (..., V)
    method: str = "greedy",
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 0.0,
) -> jax.Array:
    """Draw int32 token ids from ``logits``.

    ``method`` is "greedy" (argmax; filters/temperature are irrelevant) or
    "temp" (categorical over temperature-scaled, top-k/top-p-filtered
    logits).
    """
    if method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if method != "temp":
        raise ValueError(f"unknown sampler {method!r}")
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    lf = apply_top_k(lf, top_k)
    lf = apply_top_p(lf, top_p)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)
