"""Slot scheduler for continuous batching.

The engine owns a fixed-shape cache with ``n_slots`` batch rows; this class
owns the mapping requests -> slots.  Policy is FIFO admission: whenever a
slot is free and the queue is non-empty, the oldest queued request is
admitted (prefill runs for it, then it joins the fused per-tick decode).
Finished requests release their slot immediately, so under a steady
arrival stream the batch stays full — the whole point of continuous over
static batching: no slot idles while a long request drains.

With a paged KV cache the engine passes ``admit_ok`` (an allocator
capacity check).  A capacity-blocked queue head no longer blocks the whole
queue: admission looks at the first ``window`` queued requests (default 4)
and admits the FIRST one whose prompt fits the free pool, so one large
request waiting for pages cannot head-of-line-starve a stream of small
ones.  Queue order is otherwise preserved — the skipped head stays at the
front and is retried on every admission pass — and ``window=1`` restores
strict FIFO.

Known trade-off: the lookahead has no aging or page reservation, so on a
saturated pool where small requests keep arriving and fitting, a large
head's wait is unbounded (strict FIFO bounded it by blocking everyone
instead).  Reserving freed pages for a long-blocked head is a ROADMAP
follow-on; ``window=1`` is the escape hatch when head latency matters
more than pool utilization.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

from repro.serving.request import Request, RequestStatus


class Scheduler:
    def __init__(self, n_slots: int,
                 admit_ok: Optional[Callable[[Request], bool]] = None,
                 window: int = 4):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if window < 1:
            raise ValueError("need a lookahead window of at least 1")
        self.n_slots = n_slots
        self._admit_ok = admit_ok
        self.window = window
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots

    # -- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.status is not RequestStatus.QUEUED:
            raise ValueError(f"request {request.rid} already {request.status}")
        self.queue.append(request)

    # -- admission / release ---------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _pick(self) -> Optional[Request]:
        """First of the next ``window`` queued requests that passes
        ``admit_ok`` (bounded head-of-line lookahead), popped from the
        queue; FIFO order of the rest is untouched."""
        if self._admit_ok is None:
            return self.queue.popleft()
        for i in range(min(self.window, len(self.queue))):
            if self._admit_ok(self.queue[i]):
                req = self.queue[i]
                del self.queue[i]
                return req
        return None

    def admit(self, limit: Optional[int] = None) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue (FIFO with a bounded capacity
        lookahead); returns admissions.

        ``limit`` caps the number of admissions per call — the paged
        engine admits one at a time so each admission's block allocation
        is visible to the next ``admit_ok`` capacity check.
        """
        out = []
        for slot in self.free_slots():
            if not self.queue:
                break
            if limit is not None and len(out) >= limit:
                break
            req = self._pick()
            if req is None:
                break  # nothing in the window fits the pool
            req.status = RequestStatus.ACTIVE
            req.slot = slot
            self.slots[slot] = req
            out.append((slot, req))
        return out

    def release(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} already free")
        req.slot = None
        self.slots[slot] = None

    # -- views ------------------------------------------------------------

    def active(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
