"""Slot scheduler for continuous batching.

The engine owns a fixed-shape cache with ``n_slots`` batch rows; this class
owns the mapping requests -> slots.  Policy is FIFO admission: whenever a
slot is free and the queue is non-empty, the oldest queued request is
admitted (prefill runs for it, then it joins the fused per-tick decode).
Finished requests release their slot immediately, so under a steady
arrival stream the batch stays full — the whole point of continuous over
static batching: no slot idles while a long request drains.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.serving.request import Request, RequestStatus


class Scheduler:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots

    # -- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.status is not RequestStatus.QUEUED:
            raise ValueError(f"request {request.rid} already {request.status}")
        self.queue.append(request)

    # -- admission / release ---------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue (FIFO); returns admissions."""
        out = []
        for slot in self.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            req.status = RequestStatus.ACTIVE
            req.slot = slot
            self.slots[slot] = req
            out.append((slot, req))
        return out

    def release(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} already free")
        req.slot = None
        self.slots[slot] = None

    # -- views ------------------------------------------------------------

    def active(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
