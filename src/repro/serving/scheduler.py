"""Deadline-aware slot scheduler for continuous batching.

The engine owns a fixed-shape cache with ``n_slots`` batch rows; this class
owns the mapping requests -> slots.  Admission order is
**earliest-deadline-first**: queued requests sort by absolute deadline
(``t_submit + deadline_s``; no deadline sorts last), then by priority
(higher first), then by arrival order — so with no deadlines or priorities
set the policy degrades to the original FIFO exactly.  Finished requests
release their slot immediately, so under a steady arrival stream the batch
stays full — the whole point of continuous over static batching: no slot
idles while a long request drains.

With a paged KV cache the engine passes ``admit_ok`` (an allocator
capacity check).  A capacity-blocked queue head does not block the whole
queue: admission tries the first ``window`` candidates (default 4) in
urgency order and admits the first whose prompt fits the free pool, so
one large request waiting for pages cannot head-of-line-starve a stream
of small ones.  Queue order is otherwise preserved — the skipped head
stays the most urgent candidate and is retried on every admission pass.

**Aging** bounds the skipped head's wait (the seed's lookahead had none,
so on a saturated pool where small requests kept arriving and fitting, a
large head could starve forever): every pass that admits past a blocked
head increments its ``sched_skips``; once that exceeds ``age_limit`` the
scheduler admits *nobody else* — freed capacity accrues until the head
fits, force-admitting it ahead of smaller late arrivals.  ``window=1``
restores strict FIFO blocking (and makes aging moot).

Preempted requests re-enter through :meth:`submit` with their original
``seq`` intact, so a requeued request keeps its arrival-order seniority
and its (unchanged) deadline urgency.  :meth:`expire` sweeps queued
requests past their deadline out of the queue so the engine can finish
them as timeouts without burning a prefill on them.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

from repro.serving.request import Request, RequestStatus


class Scheduler:
    def __init__(self, n_slots: int,
                 admit_ok: Optional[Callable[[Request], bool]] = None,
                 window: int = 4, age_limit: int = 16):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if window < 1:
            raise ValueError("need a lookahead window of at least 1")
        if age_limit < 1:
            raise ValueError("need an aging limit of at least 1")
        self.n_slots = n_slots
        self._admit_ok = admit_ok
        self.window = window
        self.age_limit = age_limit
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self._seq = 0

    # -- submission -------------------------------------------------------

    def submit(self, request: Request) -> None:
        """Enqueue a QUEUED request.  First submission stamps the arrival
        sequence number; a preemption requeue re-enters here with ``seq``
        already set and keeps its seniority."""
        if request.status is not RequestStatus.QUEUED:
            raise ValueError(f"request {request.rid} already {request.status}")
        if request.seq is None:
            request.seq = self._seq
            self._seq += 1
        self.queue.append(request)

    # -- admission / release ---------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @staticmethod
    def urgency(r: Request) -> Tuple[float, int, int]:
        """Sort key: earliest absolute deadline, then priority (higher
        first), then arrival order."""
        return (r.deadline_abs(), -r.priority, r.seq if r.seq is not None
                else 1 << 62)

    def most_urgent(self) -> Optional[Request]:
        """The queued request the next admission will try first."""
        return min(self.queue, key=self.urgency) if self.queue else None

    def _pick(self) -> Optional[Request]:
        """Most urgent queued request that passes ``admit_ok``, bounded by
        the ``window`` lookahead; ``None`` when nothing in the window fits
        — or when the blocked head has aged past ``age_limit``, in which
        case capacity is reserved for it (no one may jump the aged head)."""
        if not self.queue:
            return None
        cand = sorted(self.queue, key=self.urgency)
        head = cand[0]
        if self._admit_ok is None or self._admit_ok(head):
            head.sched_skips = 0
            self.queue.remove(head)
            return head
        head.sched_skips += 1
        if head.sched_skips > self.age_limit:
            return None     # aged out: freed capacity accrues to the head
        for req in cand[1:min(self.window, len(cand))]:
            if self._admit_ok(req):
                self.queue.remove(req)
                return req
        return None

    def admit(self, limit: Optional[int] = None) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue in urgency order (bounded
        capacity lookahead + head aging); returns admissions.

        ``limit`` caps the number of admissions per call — the paged
        engine admits one at a time so each admission's block allocation
        is visible to the next ``admit_ok`` capacity check.
        """
        out = []
        for slot in self.free_slots():
            if not self.queue:
                break
            if limit is not None and len(out) >= limit:
                break
            req = self._pick()
            if req is None:
                break  # nothing in the window fits the pool
            req.status = RequestStatus.ACTIVE
            req.slot = slot
            self.slots[slot] = req
            out.append((slot, req))
        return out

    def expire(self, now: float) -> List[Request]:
        """Remove and return queued requests already past their deadline —
        the engine finishes them as timeouts instead of prefilling work
        that can no longer meet its SLO."""
        expired = [r for r in self.queue if r.deadline_abs() <= now]
        for r in expired:
            self.queue.remove(r)
        return expired

    def release(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} already free")
        req.slot = None
        self.slots[slot] = None

    # -- views ------------------------------------------------------------

    def active(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
