"""Speculative decoding: truncated-cascade self-drafting + batched verify.

ACDC makes the projections nearly free, so serving decode is tick-loop- and
attention-bound: the engine still pays one full model dispatch per token per
slot.  Speculative decoding amortizes that dispatch over several tokens —
a cheap *draft* proposes ``k`` tokens, the target model scores all of them
in ONE append-and-score program (``dist.steps.make_verify_step``), and the
engine advances each slot by its accepted prefix length.

The paper's own depth result supplies a free draft: deep ACDC cascades
approximate dense layers layer-by-layer (sections 3-4), so the SAME weights
with every cascade truncated to its first ``K_draft < K`` layers are a
cheap, progressively-worse approximation of the target model
(:class:`~repro.spec.draft.TruncatedCascadeDraft`).  Any smaller registry
config can draft instead (:class:`~repro.spec.draft.ModelDraft`).

Correctness contract (pinned by tests/test_spec_decode.py):

* **greedy** — a draft token is accepted iff it equals the target argmax
  at its position, so the committed stream is bit-identical to the
  non-speculative engine no matter how bad the draft is;
* **temperature** — standard rejection sampling (accept ``d_i`` with
  probability ``min(1, p(d_i)/q(d_i))``, resample the first rejection from
  ``norm(max(p - q, 0))``, bonus token from ``p``), which preserves the
  target sampling distribution exactly;
* **rollback** — rejected positions rewind: KV caches are set-written so a
  position rewind suffices (dense) plus returning over-mapped tail pages
  to the allocator (paged); recurrent SSM/conv state cannot rewind and is
  re-committed from per-position snapshots at the accepted length.
"""

from repro.spec.draft import DraftSource, ModelDraft, TruncatedCascadeDraft  # noqa: F401
from repro.spec.verify import (  # noqa: F401
    commit_states,
    committed_tokens,
    greedy_accept,
    rejection_accept,
)
