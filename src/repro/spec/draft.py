"""Draft sources: who proposes the k tokens the target model verifies.

Two sources behind one protocol (:class:`DraftSource`):

* :class:`TruncatedCascadeDraft` — the paper-native self-draft: the SAME
  target parameters with every stacked ACDC/AFDF cascade sliced to its
  first ``depth < K`` layers (the depth result of sections 3-4: each extra
  cascade layer refines an approximation of the dense projection, so the
  truncated model is a cheap, progressively-worse approximation of the
  target).  Optionally also drops the top ``skip_layers`` transformer
  blocks.  NOTE: riffled cascades (``sell_permute=True``) truncate poorly —
  the dropped tail composes near-identity layers WITH their interleaved
  permutations, so the truncated output is roughly a permuted version of
  the target's; draft un-riffled cascades or use :class:`ModelDraft`.
* :class:`ModelDraft` — any registry/smoke config with the same vocab as
  the target (fresh or supplied params).

Engine-side contract: the draft owns a DENSE slot cache mirroring the
engine's slot layout.  Admission prefills it; each spec tick runs ONE
fused propose program (a ``lax.scan`` of k+1 single-token append-scores:
k sampled drafts plus one advance step so the draft's own cache covers a
fully-accepted run); after verification the engine reports how many tokens
each slot actually committed and the draft rolls back — KV implicitly via
the engine's position rewind (the propose steps set-write), recurrent
SSM/conv state by re-committing the per-step snapshot at that length.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import steps as steps_mod
from repro.models import get_model
from repro.models.common import ModelConfig
from repro.serving import sampler as sampler_mod

#: SELL kinds with a stacked depth axis to truncate ((..., K, N) leaves).
CASCADE_KINDS = ("acdc", "afdf")


class DraftSource(Protocol):
    """What the engine needs from a draft."""

    def prepare(self, n_slots: int, max_len: int, k: int, sample: str,
                temperature: float, top_k: int, top_p: float) -> None: ...

    def prefill(self, slot: int, tokens, lengths, frontend_embeds) -> None: ...

    def propose(self, tokens, positions, rng): ...

    def commit(self, n_adv) -> None: ...

    def set_k(self, k: int) -> None: ...


def truncate_cascades(params: dict, depth: int) -> dict:
    """Slice every stacked cascade leaf under a ``sell`` subtree to its
    first ``depth`` layers.  Cascade leaves are ``(..., K, N)`` whatever
    the surrounding stacking (per-layer vmapped params add leading axes),
    so the depth axis is always ``-2``."""

    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {}
        for key, val in node.items():
            if key == "sell" and isinstance(val, dict):
                out[key] = {name: leaf[..., :depth, :]
                            for name, leaf in val.items()}
            else:
                out[key] = walk(val)
        return out

    return walk(params)


class _EngineDraft:
    """Shared engine-side machinery for any (model, cfg, params) draft."""

    def __init__(self, model, cfg: ModelConfig, params):
        if model.verify_step is None:
            raise ValueError(
                f"family {cfg.family!r} has no verify path to draft with")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.rec_keys = tuple(model.recurrent_keys)
        self._rec = None

    # -- engine wiring -----------------------------------------------------

    def prepare(self, n_slots: int, max_len: int, k: int, sample: str,
                temperature: float, top_k: int, top_p: float) -> None:
        self.k = k
        self._sampler_cfg = (sample, temperature, top_k, top_p)
        self._cache = self.model.init_cache(self.cfg, n_slots, max_len)
        self._template = self.model.init_cache(self.cfg, 1, max_len)
        self._prefill = jax.jit(
            steps_mod.make_prefill_step(self.model, self.cfg))
        self._insert = steps_mod.make_insert_step()
        self._propose = jax.jit(self._make_propose(
            k, sample, temperature, top_k, top_p), donate_argnums=(1,))
        self._commit = (jax.jit(self._make_commit(), donate_argnums=(0,))
                        if self.rec_keys else None)

    def set_k(self, k: int) -> None:
        """Re-point the fused propose program at a new draft length — the
        engine's degradation ladder steps ``spec_k`` down under load (and
        back up on recovery).  The slot cache and every other compiled
        program are kept; only the propose scan is rebuilt (jit caches
        each distinct k after its first trace)."""
        if k == self.k:
            return
        if k < 1:
            raise ValueError("set_k needs k >= 1; the engine disables "
                             "speculation itself at spec_k_eff=0")
        self.k = k
        self._propose = jax.jit(self._make_propose(
            k, *self._sampler_cfg), donate_argnums=(1,))

    def _make_propose(self, k: int, sample: str, temperature: float,
                      top_k: int, top_p: float):
        model, cfg, rec_keys = self.model, self.cfg, self.rec_keys

        def step(params, cache, tokens, position, rng):
            base = {key: cache[key] for key in rec_keys}

            def body(carry, i):
                tok, cache = carry
                logits, cache, _ = model.verify_step(
                    params, cache, tok[:, None], position + i, cfg)
                lg = logits[:, 0]
                nxt = sampler_mod.sample(
                    jax.random.fold_in(rng, i), lg, method=sample,
                    temperature=temperature, top_k=top_k, top_p=top_p)
                rec = {key: cache[key] for key in rec_keys}
                # rejection sampling needs the full draft distribution;
                # greedy acceptance reads only the tokens, so don't stack
                # k (B, V) logit planes per tick for nothing
                ys = (nxt, rec) if sample == "greedy" else (nxt, lg, rec)
                return (nxt, cache), ys

            # k sampled drafts + ONE advance step feeding the last draft,
            # so a fully-accepted run leaves no hole at position p + k
            (_, cache), ys = jax.lax.scan(
                body, (tokens, cache), jnp.arange(k + 1, dtype=jnp.int32))
            if sample == "greedy":
                (toks, recs), dlogits = ys, None
            else:
                toks, lgs, recs = ys
                dlogits = jnp.moveaxis(lgs[:k], 0, 1)            # (B, k, V)
            drafts = jnp.moveaxis(toks[:k], 0, 1)                # (B, k)
            rec = {key: jnp.concatenate([base[key][None], recs[key]], axis=0)
                   for key in rec_keys}                          # (k+2, ...)
            return drafts, dlogits, rec, cache

        return step

    def _make_commit(self):
        rec_keys = self.rec_keys

        def commit(cache, rec, n_adv):
            new = dict(cache)
            for key in rec_keys:
                s = rec[key]                                     # (S, L, B, ..)
                idx = n_adv.reshape((1, 1, -1) + (1,) * (s.ndim - 3))
                new[key] = jnp.take_along_axis(s, idx,
                                               axis=0)[0].astype(cache[key].dtype)
            return new

        return commit

    @property
    def cache_bytes(self) -> int:
        """Bytes held by the draft's (dense) slot cache.  NOTE: for a
        truncated-cascade self-draft this KV geometry equals the target's
        (truncation shrinks projection params, not heads/layers), so under
        a paged target it re-adds a dense slab's worth of memory — the
        engine folds it into its ``cache_bytes`` so the cost is visible;
        a paged draft cache is a ROADMAP follow-on."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self._cache))

    def prefill(self, slot: int, tokens, lengths, frontend_embeds) -> None:
        """Admission: run the draft's own prefill into the slot's row."""
        _, slot_cache = self._prefill(self.params, self._template, tokens,
                                      lengths, frontend_embeds)
        self._cache = self._insert(self._cache, slot_cache, jnp.int32(slot))

    def propose(self, tokens, positions, rng):
        """One fused dispatch: k drafts + draft logits for every slot."""
        drafts, dlogits, self._rec, self._cache = self._propose(
            self.params, self._cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(positions, jnp.int32), rng)
        return np.asarray(drafts), dlogits

    def commit(self, n_adv) -> None:
        """Roll back to each slot's committed length (KV rolls back
        implicitly via the engine's position rewind; recurrent state is
        re-committed from the propose snapshots)."""
        if self._commit is not None and self._rec is not None:
            self._cache = self._commit(self._cache, self._rec,
                                       jnp.asarray(n_adv, jnp.int32))
        self._rec = None


class TruncatedCascadeDraft(_EngineDraft):
    """Self-draft: target params with each SELL cascade cut to ``depth``."""

    def __init__(self, cfg: ModelConfig, params, depth: int,
                 skip_layers: int = 0):
        if cfg.sell_kind in CASCADE_KINDS:
            if not 1 <= depth <= cfg.sell_k:
                raise ValueError(
                    f"draft depth {depth} outside [1, {cfg.sell_k}]")
            dcfg = dataclasses.replace(cfg, sell_k=depth)
            dparams = truncate_cascades(params, depth)
            self.depth = depth
        elif skip_layers:
            # no cascades, but dropping top blocks still yields a cheaper
            # draft; depth is meaningless here
            dcfg, dparams = cfg, params
            self.depth = None
        else:
            raise ValueError(
                f"sell_kind {cfg.sell_kind!r} has no stacked cascades to "
                "truncate and skip_layers=0: the 'draft' would be the FULL "
                "target model run k+1 extra times per tick (strictly "
                "slower).  Serve an acdc/afdf SELL model, set skip_layers, "
                "or pass an explicit draft (e.g. spec.ModelDraft)")
        if skip_layers:
            if cfg.family != "decoder":
                raise ValueError(
                    "skip_layers only applies to the decoder family")
            keep = cfg.n_layers - skip_layers
            if keep < 1:
                raise ValueError(f"cannot skip {skip_layers} of "
                                 f"{cfg.n_layers} layers")
            dcfg = dataclasses.replace(dcfg, n_layers=keep)
            dparams = {**dparams, "layers": jax.tree.map(
                lambda p: p[:keep], dparams["layers"])}
        self.skip_layers = skip_layers
        super().__init__(get_model(dcfg), dcfg, dparams)


class ModelDraft(_EngineDraft):
    """Draft from any registry/smoke config sharing the target's vocab."""

    def __init__(self, cfg: ModelConfig, params=None,
                 rng: Optional[jax.Array] = None,
                 target_cfg: Optional[ModelConfig] = None):
        if target_cfg is not None and cfg.vocab_size != target_cfg.vocab_size:
            raise ValueError(
                f"draft vocab {cfg.vocab_size} != target "
                f"{target_cfg.vocab_size}")
        model = get_model(cfg)
        if params is None:
            params = model.init(rng if rng is not None
                                else jax.random.PRNGKey(0), cfg)
        super().__init__(model, cfg, params)
