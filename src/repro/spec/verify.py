"""Acceptance math for speculative decoding (device-side, jit-safe).

Notation: a slot's verify batch feeds ``T = k + 1`` tokens
``[t_0, d_1 .. d_k]`` (the pending token plus k drafts) and gets back
target logits ``L_0 .. L_k`` where ``L_i`` scores the token FOLLOWING
position ``i`` — exactly what ``decode_step`` would emit feeding the same
tokens one at a time.  Acceptance finds the longest prefix of drafts the
target agrees with (``n``), and the slot always advances by ``n + 1``
tokens: the accepted drafts ``d_1 .. d_n`` plus one token sampled from
``L_n`` (the greedy correction / rejection-resample when ``n < k``, the
bonus token when ``n == k``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_accept(logits: jax.Array, drafts: jax.Array):
    """Exact-match acceptance: ``(n_accepted (B,), next_token (B,))``.

    ``logits`` (B, k+1, V), ``drafts`` (B, k).  A draft is accepted iff it
    equals the target argmax at its position, so the committed stream is
    bit-identical to non-speculative greedy decode regardless of the draft.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, k+1)
    match = (greedy[:, :-1] == drafts).astype(jnp.int32)         # (B, k)
    n = jnp.sum(jnp.cumprod(match, axis=1), axis=1)              # (B,)
    nxt = jnp.take_along_axis(greedy, n[:, None], axis=1)[:, 0]
    return n, nxt


def rejection_accept(
    rng: jax.Array,
    logits: jax.Array,          # (B, k+1, V) target scores
    draft_logits: jax.Array,    # (B, k, V) draft scores (pre-filter)
    drafts: jax.Array,          # (B, k) tokens SAMPLED from the draft dist
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 0.0,
):
    """Standard speculative rejection sampling (Leviathan et al. 2023).

    Both distributions go through the SAME temperature / top-k / top-p
    pipeline as :func:`repro.serving.sampler.sample`, so the committed
    stream is distributed exactly as non-speculative sampling from the
    target.  Accept ``d_i`` while ``u_i q(d_i) < p(d_i)``; the first
    rejection resamples from ``norm(max(p - q, 0))``; full acceptance
    draws the bonus token from ``p`` (expressed uniformly by padding
    ``q`` with zeros at position k, where the residual reduces to ``p``).
    """
    from repro.serving import sampler as sampler_mod  # avoid import cycle

    def dist(lg):
        lf = lg.astype(jnp.float32) / max(temperature, 1e-6)
        lf = sampler_mod.apply_top_k(lf, top_k)
        lf = sampler_mod.apply_top_p(lf, top_p)
        return jax.nn.softmax(lf, axis=-1)

    b, k = drafts.shape
    p = dist(logits)                                             # (B,k+1,V)
    q = dist(draft_logits)                                       # (B,k,V)
    p_tok = jnp.take_along_axis(p[:, :k], drafts[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
    u_key, s_key = jax.random.split(rng)
    u = jax.random.uniform(u_key, (b, k))
    accept = (u * q_tok < p_tok).astype(jnp.int32)               # (B,k)
    n = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)             # (B,)

    q_pad = jnp.concatenate([q, jnp.zeros_like(p[:, :1])], axis=1)
    p_n = jnp.take_along_axis(p, n[:, None, None], axis=1)[:, 0]
    q_n = jnp.take_along_axis(q_pad, n[:, None, None], axis=1)[:, 0]
    res = jnp.maximum(p_n - q_n, 0.0)
    mass = jnp.sum(res, axis=-1, keepdims=True)
    # p == q exactly leaves no residual mass; fall back to p itself
    res = jnp.where(mass > 0, res / jnp.maximum(mass, 1e-30), p_n)
    nxt = jax.random.categorical(
        s_key, jnp.log(jnp.maximum(res, 1e-30)), axis=-1).astype(jnp.int32)
    return n, nxt


def committed_tokens(drafts: jax.Array, n: jax.Array,
                     nxt: jax.Array) -> jax.Array:
    """Assemble the committed stream ``(B, k+1)``: accepted drafts
    ``d_1 .. d_n`` then the correction/bonus token at index ``n``
    (entries beyond index ``n`` are junk the host never reads)."""
    k = drafts.shape[1]
    padded = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)   # (B, k+1)
    sel = jnp.arange(k + 1, dtype=jnp.int32)[None, :] == n[:, None]
    return jnp.where(sel, nxt[:, None], padded).astype(jnp.int32)


def commit_states(cache: dict, states: dict, n_adv: jax.Array) -> dict:
    """Re-commit recurrent cache leaves at each row's accepted length.

    ``states[key]`` is ``cache[key]`` with a time axis inserted after the
    batch axis — ``(L, B, T+1, ...)``, index j = state after j consumed
    tokens — and ``n_adv (B,)`` is the per-row consumed count (0 for
    parked/stalled rows, which therefore keep their incoming state).
    """
    new = dict(cache)
    for key, s in states.items():
        idx = n_adv.reshape((1, -1, 1) + (1,) * (s.ndim - 3))
        sel = jnp.take_along_axis(s, idx, axis=2)[:, :, 0]
        new[key] = sel.astype(cache[key].dtype)
    return new
