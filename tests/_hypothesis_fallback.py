"""Deterministic stand-in for ``hypothesis`` on minimal installs.

The property tests in this suite only use ``@given`` with ``st.integers``
and ``st.sampled_from`` plus ``@settings(max_examples=..., deadline=None)``.
When hypothesis is unavailable (the offline container has no wheel), this
shim replays each property over a fixed, seeded sample of the strategy
space — strictly weaker than real shrinking/search, but the properties
still execute and the suite collects.  Test modules import it as::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # minimal install
        from _hypothesis_fallback import given, settings, strategies as st
"""

from __future__ import annotations

import random


class _Strategy:
    def sample(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def sample(self, rng: random.Random) -> int:
        # bias toward the boundaries, where the bugs live
        r = rng.random()
        if r < 0.15:
            return self.lo
        if r < 0.3:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng: random.Random):
        return rng.choice(self.options)


class _StrategiesModule:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        return _SampledFrom(options)


strategies = _StrategiesModule()


def settings(max_examples: int = 10, deadline=None, **_kwargs):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        # NOT functools.wraps: pytest would follow __wrapped__ to the
        # original signature and look for fixtures named after the drawn
        # parameters.  The wrapper must present a zero-arg signature.
        def wrapper():
            # read at call time: @settings may sit above @given (attribute
            # lands on this wrapper) or below it (lands on fn)
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 10))
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            for i in range(n):
                drawn = tuple(s.sample(rng) for s in strats)
                try:
                    fn(*drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: "
                        f"args={drawn!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
