"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
