"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""

import jax
import pytest

# jax < 0.5 constructs AbstractMesh from shape_tuple=((name, size), ...);
# newer releases take (axis_sizes, axis_names).  The sharding tests use the
# newer calling convention — adapt on old installs so one suite serves both.
try:
    jax.sharding.AbstractMesh((1,), ("_probe",))
except TypeError:
    _ABSTRACT_MESH = jax.sharding.AbstractMesh

    def _abstract_mesh_compat(axis_sizes, axis_names=None, *args, **kwargs):
        if axis_names is None:
            return _ABSTRACT_MESH(axis_sizes, *args, **kwargs)
        return _ABSTRACT_MESH(tuple(zip(axis_names, axis_sizes)),
                              *args, **kwargs)

    jax.sharding.AbstractMesh = _abstract_mesh_compat
except AttributeError:
    pass  # jax predates AbstractMesh: let the tests that need it fail alone


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
