"""Regenerate the ``family='acdc'`` bit-identity goldens.

    JAX_PLATFORMS=cpu PYTHONPATH=src python tests/goldens/gen_acdc_goldens.py

Captures, on CPU (the CI backend the pins run on):

* greedy continuous-batching engine token streams for the qwen3 smoke
  config with ACDC SELL projections on the fused Pallas path, and
* raw fused-cascade VJP cotangents (dx/da/dd) for a fixed operand set,

into ``acdc_goldens.json``.  ``tests/test_families.py`` asserts the live
code reproduces both EXACTLY (token equality, bitwise float equality) —
the guard that the pluggable-transform refactor left the paper's DCT
family untouched.  Only regenerate after an intentional numerics change.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def engine_streams():
    import dataclasses

    from repro.configs import registry
    from repro.models import get_model
    from repro.serving import Engine, Request

    cfg = registry.get_smoke_config("qwen3_1_7b")
    cfg = dataclasses.replace(cfg, sell_kind="acdc", sell_method="pallas")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.RandomState(7)
    reqs = [
        Request(rid=i,
                prompt=rs.randint(0, cfg.vocab_size,
                                  size=rs.randint(4, 12)).tolist(),
                max_new_tokens=8)
        for i in range(5)
    ]
    eng = Engine(model, cfg, params, n_slots=2, max_len=24,
                 max_prompt_len=12)
    eng.run(reqs, max_ticks=400)
    return {
        "prompts": [r.prompt for r in reqs],
        "generated": [list(map(int, r.generated)) for r in reqs],
    }


def cascade_grads():
    from repro.kernels import ops

    n, k, m = 128, 3, 8
    r = jax.random.PRNGKey(41)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    b = 0.05 * jax.random.normal(jax.random.fold_in(r, 3), (k, n))
    g = jax.random.normal(jax.random.fold_in(r, 4), (m, n))

    y, vjp = jax.vjp(
        lambda x, a, d, b: ops.acdc_cascade_op(x, a, d, b, relu=True,
                                               permute=True), x, a, d, b)
    dx, da, dd, db = vjp(g)

    def pin(arr):
        flat = np.asarray(arr, np.float32).ravel()
        # first 8 raw IEEE words (bitwise pin) + a float64 checksum
        return {
            "head_bits": [int(w) for w in
                          flat[:8].view(np.uint32)],
            "checksum": float(np.float64(flat).sum()),
        }

    return {
        "y": pin(y), "dx": pin(dx), "da": pin(da), "dd": pin(dd),
        "db": pin(db),
    }


def main():
    out = {
        "backend": jax.default_backend(),
        "engine": engine_streams(),
        "cascade_vjp": cascade_grads(),
    }
    path = os.path.join(os.path.dirname(__file__), "acdc_goldens.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
