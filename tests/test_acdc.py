"""ACDC layer tests: definition, cascades, init, paper gradient equations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import acdc as A
from repro.core import transforms as T


def _rand_layer(n, seed=0, std=0.1):
    r = np.random.RandomState(seed)
    a = (1 + std * r.randn(n)).astype(np.float32)
    d = (1 + std * r.randn(n)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(d)


@pytest.mark.parametrize("n", [8, 32, 100, 256])
@pytest.mark.parametrize("method", ["fft", "matmul"])
def test_acdc_definition(n, method):
    """y = ((x*a) C * d) C^T with the explicit orthonormal DCT matrix."""
    a, d = _rand_layer(n, seed=n)
    x = jnp.asarray(np.random.RandomState(1).randn(4, n).astype(np.float32))
    c = np.asarray(T.dct_matrix(n))
    want = ((np.asarray(x) * np.asarray(a)) @ c * np.asarray(d)) @ c.T
    got = np.asarray(A.acdc(x, a, d, method=method))
    np.testing.assert_allclose(got, want, atol=2e-4)


def test_acdc_bias_on_d():
    n = 16
    a, d = _rand_layer(n)
    bias = jnp.asarray(np.random.RandomState(2).randn(n).astype(np.float32))
    x = jnp.ones((2, n))
    c = np.asarray(T.dct_matrix(n))
    want = ((np.asarray(x) * np.asarray(a)) @ c * np.asarray(d)
            + np.asarray(bias)) @ c.T
    np.testing.assert_allclose(np.asarray(A.acdc(x, a, d, bias)), want,
                               atol=1e-5)


@given(st.integers(4, 64), st.integers(1, 5), st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_cascade_equals_dense_equivalent(n, k, seed):
    """Property: a linear ACDC_K cascade acts as one dense matrix."""
    cfg = A.ACDCConfig(n=n, k=k, bias=False)
    p = A.init_acdc_params(jax.random.PRNGKey(seed), cfg)
    w = np.asarray(A.acdc_cascade_dense_equivalent(p, cfg))
    x = np.random.RandomState(seed).randn(3, n).astype(np.float32)
    got = np.asarray(A.acdc_cascade(p, jnp.asarray(x), cfg))
    np.testing.assert_allclose(x @ w, got, atol=5e-3)


def test_cascade_composition():
    """ACDC_2(x) == ACDC_1(ACDC_1(x)) with matching per-layer params."""
    n = 32
    cfg2 = A.ACDCConfig(n=n, k=2)   # bias-on-D enabled (default)
    p = A.init_acdc_params(jax.random.PRNGKey(3), cfg2)
    x = jnp.asarray(np.random.RandomState(0).randn(2, n).astype(np.float32))
    y2 = A.acdc_cascade(p, x, cfg2)
    y_manual = A.acdc(A.acdc(x, p["a"][0], p["d"][0], p["bias"][0]),
                      p["a"][1], p["d"][1], p["bias"][1])
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_manual), atol=1e-5)


def test_identity_init_is_near_identity():
    """Paper init N(1, sigma^2): at sigma->0 the layer is the identity
    (A=D=I and C C^T = I)."""
    n = 64
    cfg = A.ACDCConfig(n=n, k=4, init_std=0.0, bias=False)
    p = A.init_acdc_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.RandomState(0).randn(3, n).astype(np.float32))
    y = A.acdc_cascade(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


def test_first_a_identity_convention():
    cfg = A.ACDCConfig(n=16, k=3, first_a_identity=True, bias=False)
    p = A.init_acdc_params(jax.random.PRNGKey(1), cfg)
    np.testing.assert_allclose(np.asarray(p["a"][0]), np.ones(16))


def test_paper_gradients_eq10_to_14():
    """Backward formulas (10)-(14) against autodiff."""
    n = 24
    a, d = _rand_layer(n, seed=5)
    x = jnp.asarray(np.random.RandomState(6).randn(3, n).astype(np.float32))
    g = jnp.asarray(np.random.RandomState(7).randn(3, n).astype(np.float32))

    def f(x, a, d):
        return jnp.sum(A.acdc(x, a, d) * g)   # dL/dy = g

    gx, ga, gd = jax.grad(f, argnums=(0, 1, 2))(x, a, d)
    c = np.asarray(T.dct_matrix(n))
    xn, an, dn, gn = map(np.asarray, (x, a, d, g))
    gc = gn @ c                                   # g C
    h2 = (xn * an) @ c
    want_d = (h2 * gc).sum(0)                     # eq. 10
    dh1 = (gc * dn) @ c.T
    want_a = (xn * dh1).sum(0)                    # eq. 12
    want_x = an * dh1                             # eq. 14
    np.testing.assert_allclose(np.asarray(gd), want_d, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ga), want_a, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx), want_x, atol=1e-4)


@pytest.mark.parametrize("n_in,n_out", [(10, 20), (32, 16), (100, 100)])
def test_rectangular_pad_truncate(n_in, n_out):
    n = A.rectangular_size(n_in, n_out)
    cfg = A.ACDCConfig(n=n, k=2)
    p = A.init_acdc_params(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((5, n_in))
    y = A.acdc_rectangular(p, x, cfg, n_in, n_out)
    assert y.shape == (5, n_out)
    # consistency with explicit pad+truncate
    xp = jnp.pad(x, ((0, 0), (0, n - n_in)))
    want = A.acdc_cascade(p, xp, cfg)[..., :n_out]
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)


def test_rectangular_size_lane_alignment():
    assert A.rectangular_size(100, 60) == 100
    assert A.rectangular_size(100, 60, multiple=128) == 128
    assert A.rectangular_size(2048, 6144, multiple=128) == 6144


def test_relu_permute_cascade_shapes_and_nonlinearity():
    n = 32
    cfg = A.ACDCConfig(n=n, k=3, relu=True, permute=True)
    p = A.init_acdc_params(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.RandomState(1).randn(4, n).astype(np.float32))
    y1 = A.acdc_cascade(p, x, cfg)
    y2 = A.acdc_cascade(p, -x, cfg)
    assert y1.shape == x.shape
    # ReLU breaks oddness: f(-x) != -f(x)  (a linear cascade would be odd)
    assert float(jnp.abs(y2 + y1).max()) > 1e-3
