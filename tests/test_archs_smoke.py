"""Per-assigned-architecture smoke tests (deliverable f).

Each of the ten architectures instantiates its REDUCED config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs; decode
paths produce finite logits.  The FULL configs are exercised only through
the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.dist import steps as steps_mod
from repro.models import get_model
from repro.optim import OptimizerConfig, constant_schedule, make_optimizer


def _batch(cfg, b=2, s=32, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "audio":
        batch["frontend_embeds"] = jax.random.normal(
            rng, (b, cfg.n_frontend_tokens or 8, cfg.d_model))
    elif cfg.frontend == "vision":
        fe = jax.random.normal(rng, (b, cfg.n_frontend_tokens, cfg.d_model))
        batch["frontend_embeds"] = fe
    return batch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits = model.apply(params, batch["tokens"], cfg,
                         batch.get("frontend_embeds"))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_one_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    opt = make_optimizer(OptimizerConfig(lr=1e-3), constant_schedule(1e-3))
    step = steps_mod.make_train_step(model, cfg, opt)
    state = steps_mod.init_state(model, cfg, opt, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_decode_step(arch):
    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = model.init_cache(cfg, b, 64)
    if cfg.family == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (b, cfg.n_frontend_tokens or 8, cfg.d_model))
        cache = model.module.prefill_cross(params, cache, frames, cfg)
    toks = jnp.zeros((b,), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, toks, pos, cfg)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache changed
    diff = jax.tree.map(lambda a, b_: float(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)).max()), cache, cache2)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mamba2_1_3b",
                                  "deepseek_moe_16b"])
def test_smoke_acdc_sell_variant(arch):
    """Every family runs with ACDC projections (the paper's technique)."""
    import dataclasses
    cfg = dataclasses.replace(registry.get_smoke_config(arch),
                              sell_kind="acdc", sell_k=2)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss = model.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_full_config_matches_assignment(arch):
    """Exact assigned hyperparameters (paper-pool table)."""
    cfg = registry.get_config(arch)
    expected = {
        "deepseek_67b": dict(n_layers=95, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=22016, vocab_size=102400),
        "chatglm3_6b": dict(n_layers=28, d_model=4096, n_heads=32,
                            n_kv_heads=2, d_ff=13696, vocab_size=65024),
        "gemma3_27b": dict(n_layers=62, d_model=5376, n_heads=32,
                           n_kv_heads=16, d_ff=21504, vocab_size=262144),
        "qwen3_1_7b": dict(n_layers=28, d_model=2048, n_heads=16,
                           n_kv_heads=8, d_ff=6144, vocab_size=151936),
        "seamless_m4t_large_v2": dict(n_layers=24, d_model=1024, n_heads=16,
                                      n_kv_heads=16, d_ff=8192,
                                      vocab_size=256206),
        "mamba2_1_3b": dict(n_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128),
        "moonshot_v1_16b_a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    n_kv_heads=16, d_ff=1408,
                                    vocab_size=163840, n_experts=64, top_k=6),
        "deepseek_moe_16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 n_kv_heads=16, d_ff=1408,
                                 vocab_size=102400, n_experts=64, top_k=6),
        "zamba2_1_2b": dict(n_layers=38, d_model=2048, n_heads=32,
                            n_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
        "llava_next_34b": dict(n_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab_size=64000),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
