"""Checkpoint manager: roundtrip, atomicity, keep-k GC, async, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(v=0.0):
    return {
        "params": {"w": jnp.full((4, 4), v), "b": jnp.full((4,), v + 1)},
        "opt": {"m": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}},
        "step": jnp.asarray(int(v), jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state(3.0)
    mgr.save(10, st, extra={"arch": "x"})
    out = mgr.restore(10, jax.eval_shape(lambda: st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert mgr.extra(10)["arch"] == "x"


def test_latest_and_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _state(float(s)))
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]  # 1, 2 garbage-collected


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(7, _state(7.0))
    mgr.wait()
    assert mgr.latest_step() == 7
    out = mgr.restore(7, jax.eval_shape(lambda: _state(7.0)))
    assert float(out["params"]["w"][0, 0]) == 7.0


def test_elastic_restore_resharding(tmp_path):
    """Save under one sharding, restore under another (mesh change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    st = _state(2.0)
    mgr.save(1, st)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    out = mgr.restore(1, jax.eval_shape(lambda: st), sh)
    assert out["params"]["w"].sharding == NamedSharding(mesh, P())
    np.testing.assert_allclose(np.asarray(out["params"]["w"]),
                               np.asarray(st["params"]["w"]))


def test_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        mgr.restore(1, jax.eval_shape(lambda: {"a": jnp.zeros(2),
                                               "b": jnp.zeros(2)}))


def test_overwrite_same_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros(2)})
    mgr.save(1, {"a": jnp.ones(2)})
    out = mgr.restore(1, jax.eval_shape(lambda: {"a": jnp.zeros(2)}))
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones(2))
