"""Gradient compression: quantization error bounds + error feedback +
compressed psum under shard_map (multi-device via forked CPU devices is not
available here, so the collective path runs on a 1-device mesh; numerics of
quantize/EF are the meat)."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.dist import compression as C


@given(st.integers(1, 2000), st.integers(0, 444))
@settings(max_examples=30, deadline=None)
def test_quantize_error_bound_property(n, seed):
    x = jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))
    q, scale = C.quantize_int8(x)
    xhat = C.dequantize_int8(q, scale, n)
    # per-block max-abs scaling: |err| <= scale/2 elementwise
    blocks = int(np.ceil(n / C.BLOCK))
    per_elem_bound = np.repeat(np.asarray(scale)[:, 0], C.BLOCK)[:n] * 0.5 + 1e-7
    assert bool((np.abs(np.asarray(x - xhat)) <= per_elem_bound).all())


def test_quantize_exact_on_grid():
    """Values already on the int8 grid reconstruct exactly."""
    scale = 0.5
    x = jnp.asarray(np.arange(-127, 128, dtype=np.float32) * scale)
    q, s = C.quantize_int8(x)
    xhat = C.dequantize_int8(q, s, x.shape[0])
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(x), atol=1e-6)


def test_error_feedback_converges():
    """With EF, the *accumulated* transmitted signal tracks the true sum of
    gradients: || sum(g) - sum(ghat) || stays bounded by one quantization
    step instead of growing with T."""
    rng = np.random.RandomState(0)
    n, T = 512, 50
    err = jnp.zeros((n,), jnp.float32)
    true_sum = np.zeros(n, np.float32)
    sent_sum = np.zeros(n, np.float32)
    for t in range(T):
        g = jnp.asarray(rng.randn(n).astype(np.float32))
        flat = g + err
        q, s = C.quantize_int8(flat)
        ghat = C.dequantize_int8(q, s, n)
        err = flat - ghat
        true_sum += np.asarray(g)
        sent_sum += np.asarray(ghat)
    resid = np.abs(true_sum - sent_sum)
    # residual equals |err| <= max scale /2, NOT O(T)
    assert resid.max() < 0.1, resid.max()


def test_compressed_psum_single_device_semantics():
    """On a 1-member axis, compressed_psum returns the dequantized local
    gradient and the quantization residual as new error."""
    from jax.sharding import Mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.RandomState(1).randn(64).astype(np.float32))
    e = jnp.zeros_like(g)

    def f(g, e):
        return C.compressed_psum(g, e, "pod")

    ghat, new_e = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))(g, e)
    np.testing.assert_allclose(np.asarray(ghat + new_e), np.asarray(g),
                               atol=1e-5)
    # error is bounded by half a quantization step
    q, s = C.quantize_int8(g)
    assert float(jnp.abs(new_e).max()) <= float(s.max()) / 2 + 1e-6


def test_make_error_state_structure():
    params = {"a": jnp.zeros((3, 4), jnp.bfloat16), "b": jnp.zeros((5,))}
    es = C.make_error_state(params)
    assert es["a"].shape == (3, 4) and es["a"].dtype == jnp.float32
    assert es["b"].shape == (5,)
