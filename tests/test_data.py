"""Data pipeline: determinism, shardability, learnable structure, specs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLM, make_batch_specs


def test_deterministic_across_calls():
    p = SyntheticLM(DataConfig(vocab_size=100, seq_len=32, global_batch=4))
    b1 = p.batch_at(7)
    b2 = p.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_different_steps_differ():
    p = SyntheticLM(DataConfig(vocab_size=100, seq_len=32, global_batch=4))
    assert not np.array_equal(np.asarray(p.batch_at(0)["tokens"]),
                              np.asarray(p.batch_at(1)["tokens"]))


def test_shards_partition_global_batch():
    p = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    full = p.batch_at(3)
    parts = [p.shard_at(3, i, 4) for i in range(4)]
    rebuilt = np.concatenate([np.asarray(q["tokens"]) for q in parts], axis=0)
    np.testing.assert_array_equal(rebuilt, np.asarray(full["tokens"]))


def test_labels_are_shifted_tokens():
    p = SyntheticLM(DataConfig(vocab_size=50, seq_len=16, global_batch=2))
    b = p.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert bool((b["labels"][:, -1] == -1).all())


def test_tokens_in_range():
    cfg = DataConfig(vocab_size=37, seq_len=64, global_batch=4)
    b = SyntheticLM(cfg).batch_at(11)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < 37


def test_markov_structure_is_learnable():
    """~half of next-tokens are the deterministic map of the previous one."""
    cfg = DataConfig(vocab_size=1000, seq_len=512, global_batch=4)
    b = SyntheticLM(cfg).batch_at(0)
    t = np.asarray(b["tokens"]).astype(np.uint32)
    det = (t[:, :-1] * np.uint32(2654435761) + np.uint32(12345)) % np.uint32(1000)
    frac = (det == t[:, 1:]).mean()
    # one vectorized rewrite pass: a transition survives as deterministic
    # when coin_i is True AND token i itself was not rewritten (~0.25)
    assert 0.15 < frac < 0.5, frac


def test_frontend_stub_shapes():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=2,
                     frontend="vision", n_frontend_tokens=8, d_model=16)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["frontend_embeds"].shape == (2, 8, 16)
    # vision prefix positions are masked out of the loss
    assert bool((b["labels"][:, :8] == -1).all())


def test_batch_specs_match_real_batch():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=2,
                     frontend="audio", n_frontend_tokens=8, d_model=16)
    spec = make_batch_specs(cfg)
    real = SyntheticLM(cfg).batch_at(0)
    for k, s in spec.items():
        assert real[k].shape == s.shape, k
        assert real[k].dtype == s.dtype, k
