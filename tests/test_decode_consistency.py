"""Prefill/teacher-forced logits must equal step-by-step decode logits.

This is the strongest correctness invariant for the serving path: the KV
cache, RoPE position handling, sliding windows, SSM recurrence and the
chunked-SSD <-> recurrent duality are all covered by one check per family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model

FAMS = ["qwen3_1_7b", "gemma3_27b", "chatglm3_6b", "mamba2_1_3b",
        "zamba2_1_2b", "deepseek_moe_16b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_prefill(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.n_experts:
        # capacity-based token-choice MoE drops depend on how many tokens
        # compete per step, so prefill==decode only holds when routing is
        # dropless; raise capacity so no slot is ever dropped.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (b, s), 0,
                              cfg.vocab_size)

    full = model.apply(params, toks, cfg)          # (B, S, V) teacher-forced

    cache = model.init_cache(cfg, b, s + 1)
    step_logits = []
    for i in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, i],
                                      jnp.full((b,), i, jnp.int32), cfg)
        step_logits.append(lg)
    dec = jnp.stack(step_logits, axis=1)           # (B, S, V)

    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), atol=2e-3, rtol=1e-2)


def test_decode_matches_prefill_encdec():
    cfg = registry.get_smoke_config("seamless_m4t_large_v2")
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    b, s = 2, 12
    frames = jax.random.normal(rng, (b, cfg.n_frontend_tokens, cfg.d_model))
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (b, s), 0,
                              cfg.vocab_size)
    full = model.apply(params, toks, cfg, frames)

    cache = model.init_cache(cfg, b, s + 1)
    cache = model.module.prefill_cross(params, cache, frames, cfg)
    outs = []
    for i in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, i],
                                      jnp.full((b,), i, jnp.int32), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=1e-2)


def test_sliding_window_decode_consistency():
    """Windowed attention must agree between masked-prefill and cache
    decode even when the window has rolled past old tokens."""
    cfg = dataclasses.replace(registry.get_smoke_config("gemma3_27b"),
                              sliding_window=4, global_every=3)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    b, s = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full = model.apply(params, toks, cfg)
    cache = model.init_cache(cfg, b, s + 1)
    outs = []
    for i in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, i],
                                      jnp.full((b,), i, jnp.int32), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=1e-2)


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mamba2_1_3b"])
def test_serve_step_greedy_matches_prefill_argmax(arch):
    """The dist.steps serve-step builder must agree with teacher forcing:
    feeding the prompt through greedy ``make_serve_step`` yields exactly the
    argmax of the prefill logits at every position (one transformer arch
    with a KV cache, one SSM arch with recurrent state)."""
    from repro.dist import steps as steps_mod

    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (b, s), 0,
                              cfg.vocab_size)

    full = model.apply(params, toks, cfg)                 # (B, S, V)
    want = np.asarray(jnp.argmax(full, axis=-1))

    serve = jax.jit(steps_mod.make_serve_step(model, cfg, sample="greedy"))
    cache = model.init_cache(cfg, b, s + 1)
    got = []
    for i in range(s):
        nxt, cache = serve(params, cache, toks[:, i],
                           jnp.full((b,), i, jnp.int32), rng)
        got.append(nxt)
    got = np.asarray(jnp.stack(got, axis=1))              # (B, S)
    np.testing.assert_array_equal(got, want)

    # temperature sampling: same decode path, valid ids, rng-deterministic
    temp = jax.jit(steps_mod.make_serve_step(model, cfg, sample="temp",
                                             temperature=0.7))
    cache = model.init_cache(cfg, b, s + 1)
    t1, _ = temp(params, cache, toks[:, 0], jnp.zeros((b,), jnp.int32), rng)
    t2, _ = temp(params, cache, toks[:, 0], jnp.zeros((b,), jnp.int32), rng)
    assert t1.dtype == jnp.int32
    assert bool((t1 >= 0).all()) and bool((t1 < cfg.vocab_size).all())
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


# ---------------------------------------------------------------------------
# Batched prefill: one lowered program must reproduce the token-at-a-time
# decode path — logits AND cache state — for every model family.
# ---------------------------------------------------------------------------

PREFILL_FAMS = [
    ("qwen3_1_7b", "decoder"),
    ("mamba2_1_3b", "ssm"),
    ("zamba2_1_2b", "hybrid"),
    ("seamless_m4t_large_v2", "encdec"),
    ("deepseek_moe_16b", "moe"),
]


def _prefill_fixture(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.n_experts:
        # dropless routing, as in test_decode_matches_prefill: capacity-based
        # drops depend on how many tokens compete per dispatch
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (b, s), 0,
                              cfg.vocab_size)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (b, cfg.n_frontend_tokens,
                                         cfg.d_model))
    return cfg, model, params, toks, frames


@pytest.mark.parametrize("arch", [a for a, _ in PREFILL_FAMS])
def test_prefill_matches_decode_logits(arch):
    """Ragged batched prefill == step-by-step decode at every valid
    position, for all four families (+ MoE): the acceptance invariant for
    the serving engine's admission path."""
    cfg, model, params, toks, frames = _prefill_fixture(arch)
    b, s = toks.shape
    lengths = jnp.array([s, s - 5], jnp.int32)

    cache = model.init_cache(cfg, b, s + 4)
    logits_pre, cache_pre = model.prefill(params, cache, toks, cfg, lengths,
                                          frames)

    cache_seq = model.init_cache(cfg, b, s + 4)
    if cfg.family == "encdec":
        cache_seq = model.module.prefill_cross(params, cache_seq, frames, cfg)
    outs = []
    for i in range(s):
        lg, cache_seq = model.decode_step(params, cache_seq, toks[:, i],
                                          jnp.full((b,), i, jnp.int32), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)

    for row in range(b):
        ln = int(lengths[row])
        np.testing.assert_allclose(
            np.asarray(logits_pre[row, :ln]), np.asarray(dec[row, :ln]),
            atol=2e-3, rtol=1e-2)

    # the caches must also agree: continue decoding one step from each and
    # compare — this catches KV scatter, RoPE offset and SSM-state bugs that
    # the prompt logits alone cannot see.  Row 1 is ragged (length s-5), so
    # its continuation runs at position s-5 in the padded batch.
    nxt = jnp.argmax(
        jnp.take_along_axis(logits_pre, (lengths - 1)[:, None, None],
                            axis=1)[:, 0], axis=-1).astype(jnp.int32)
    lg_a, _ = model.decode_step(params, cache_pre, nxt, lengths, cfg)
    # full-length row 0: sequential cache is positioned at s == lengths[0]
    lg_b, _ = model.decode_step(params, cache_seq, nxt, lengths, cfg)
    np.testing.assert_allclose(np.asarray(lg_a[0]), np.asarray(lg_b[0]),
                               atol=2e-3, rtol=1e-2)

    # ragged row 1: reference is feeding ONLY its l tokens alone
    ln = int(lengths[1])
    cache_1 = model.init_cache(cfg, 1, s + 4)
    if cfg.family == "encdec":
        cache_1 = model.module.prefill_cross(params, cache_1, frames[1:2],
                                             cfg)
    for i in range(ln):
        _, cache_1 = model.decode_step(params, cache_1, toks[1:2, i],
                                       jnp.full((1,), i, jnp.int32), cfg)
    lg_solo, _ = model.decode_step(params, cache_1, nxt[1:2],
                                   jnp.full((1,), ln, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lg_a[1]), np.asarray(lg_solo[0]),
                               atol=2e-3, rtol=1e-2)


def test_prefill_step_builder_last_logits():
    """make_prefill_step picks each row's last REAL position's logits."""
    from repro.dist import steps as steps_mod

    cfg, model, params, toks, _ = _prefill_fixture("qwen3_1_7b")
    b, s = toks.shape
    lengths = jnp.array([s, s - 7], jnp.int32)
    cache = model.init_cache(cfg, b, s + 2)
    full_step = steps_mod.make_prefill_step(model, cfg, full_logits=True)
    last_step = steps_mod.make_prefill_step(model, cfg)
    full, _ = full_step(params, cache, toks, lengths)
    last, _ = last_step(params, cache, toks, lengths)
    for row in range(b):
        np.testing.assert_allclose(
            np.asarray(last[row]), np.asarray(full[row, int(lengths[row]) - 1]),
            atol=0, rtol=0)


def test_ssd_chunked_equals_recurrence():
    """State-space duality: the chunked (train) algorithm equals the naive
    recurrent scan for random inputs."""
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, n = 2, 32, 3, 4, 8
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(b, s, h, p).astype(np.float32))
    a_log = jnp.asarray((-0.5 + 0.1 * rng.randn(b, s, h)).astype(np.float32))
    bm = jnp.asarray(rng.randn(b, s, n).astype(np.float32))
    cm = jnp.asarray(rng.randn(b, s, n).astype(np.float32))

    got = np.asarray(ssd_chunked(x, a_log, bm, cm, chunk=8))

    # reference recurrence
    state = np.zeros((b, h, p, n), np.float32)
    want = np.zeros((b, s, h, p), np.float32)
    xn, an, bn, cn = map(np.asarray, (x, a_log, bm, cm))
    for t in range(s):
        decay = np.exp(an[:, t])[:, :, None, None]
        state = state * decay + np.einsum("bhp,bn->bhpn", xn[:, t], bn[:, t])
        want[:, t] = np.einsum("bhpn,bn->bhp", state, cn[:, t])
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
