"""End-to-end elastic restart drill: kill -> shrink -> resume.

Phase 1 trains on 8 (forced host) devices with the mesh resolved by
``ElasticPolicy`` (data=4, model=2), gets SIGTERM'd mid-run, and must
drain: checkpoint the in-flight state and exit cleanly.  Phase 2 restarts
with half the devices — simulating the loss of a replica — resolves the
shrunken (data=2, model=2) mesh, restores the SAME checkpoint onto it
(the manager stores global-layout arrays, so restore re-shards), and
trains to completion.  This is the ROADMAP drill item: ``resolve_mesh``
and elastic checkpoint restore exercised together, not separately.

Runs in subprocesses because the forced device count must be set before
jax initializes (tests otherwise see the real single CPU device).
"""

import os
import selectors
import signal
import subprocess
import sys
import time

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return env


def _cmd(steps: int, ckpt_dir: str, resume: bool = False):
    cmd = [sys.executable, "-u", "-m", "repro.launch.train",
           "--arch", "qwen3_1_7b", "--smoke", "--steps", str(steps),
           "--seq-len", "32", "--global-batch", "8",
           "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
           "--model-parallel", "2", "--log-every", "1"]
    if resume:
        cmd.append("--resume")
    return cmd


@pytest.mark.slow
def test_elastic_kill_shrink_resume(tmp_path):
    ckpt = str(tmp_path / "ckpt")

    # -- phase 1: 8 devices, SIGTERM after a few steps ---------------------
    proc = subprocess.Popen(
        _cmd(steps=60, ckpt_dir=ckpt), cwd=REPO, env=_env(8),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    # select-based read so a hung child hits OUR deadline instead of
    # blocking the stdout iteration forever
    sel = selectors.DefaultSelector()
    sel.register(proc.stdout, selectors.EVENT_READ)
    lines = []
    sent = False
    deadline = time.time() + 420
    while time.time() < deadline and not sent:
        if not sel.select(timeout=10):
            continue
        line = proc.stdout.readline()
        if not line:
            break
        lines.append(line)
        if line.startswith("step") and int(line.split()[1]) >= 3:
            proc.send_signal(signal.SIGTERM)
            sent = True
    if not sent:
        proc.kill()
        pytest.fail("phase 1 never reached step 3:\n" + "".join(lines)[-2000:])
    rest, _ = proc.communicate(timeout=300)
    out1 = "".join(lines) + rest
    assert proc.returncode == 0, out1
    assert "[elastic] resolved mesh data=4 model=2 from 8 devices" in out1
    assert "[preempt] SIGTERM received" in out1

    from repro.checkpoint import CheckpointManager
    saved = CheckpointManager(ckpt).latest_step()
    assert saved is not None and saved >= 3, out1
    assert saved < 60, "drain must not mislabel the final step"

    # -- phase 2: half the devices, resume onto the shrunken mesh ----------
    final_steps = saved + 4
    out2 = subprocess.run(
        _cmd(steps=final_steps, ckpt_dir=ckpt, resume=True), cwd=REPO,
        env=_env(4), capture_output=True, text=True, timeout=420)
    assert out2.returncode == 0, out2.stdout + out2.stderr
    assert "[elastic] resolved mesh data=2 model=2 from 4 devices" \
        in out2.stdout
    assert f"resumed from step {saved}" in out2.stdout
    assert f"step {final_steps - 1:5d}" in out2.stdout
    assert "done." in out2.stdout
    assert CheckpointManager(ckpt).latest_step() == final_steps
