"""Transform-family registry tests (core/families.py) + acdc golden pins.

Three layers of guarantees:

1. registry contract — every registered family supplies a real
   orthonormal ``(C, C^-1 = C^T)`` pair whose fast apply/inverse match
   the explicit matrices;
2. end-to-end parity — ``kind='acdc'`` SELLs under every family and
   every method (matmul / fft / pallas) agree with their own dense
   equivalent, and ``--sell-transform`` reaches the serving engine;
3. bit-identity — ``family='acdc'`` reproduces the pre-registry code
   EXACTLY: greedy engine token streams and raw fused-cascade VJP words
   are pinned against ``tests/goldens/acdc_goldens.json`` (regenerate
   only via ``tests/goldens/gen_acdc_goldens.py`` after an intentional
   numerics change).
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import acdc as A
from repro.core import families as F
from repro.core import sell as S

FAMILIES = ["acdc", "circulant", "hadamard"]

GOLDENS = os.path.join(os.path.dirname(__file__), "goldens",
                       "acdc_goldens.json")


# ---------------------------------------------------------------------------
# Registry contract.
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert F.available() == ("acdc", "circulant", "hadamard")
    with pytest.raises(ValueError, match="unknown transform family"):
        F.get_family("wavelet")


def test_register_last_wins():
    fam = F.get_family("acdc")
    shadow = dataclasses.replace(fam, complex_diagonals=True)
    try:
        F.register(shadow)
        assert F.get_family("acdc") is shadow
    finally:
        F.register(fam)
    assert F.get_family("acdc") is fam


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("n", [16, 128])
def test_family_matrices_orthonormal(family, n):
    fam = F.get_family(family)
    n = fam.valid_size(n)
    c, ct = fam.matrices(n)
    np.testing.assert_allclose(np.asarray(c) @ np.asarray(ct), np.eye(n),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ct), np.asarray(c).T, atol=1e-6)
    assert not fam.complex_diagonals  # Pallas kernels require real diags


@pytest.mark.parametrize("family", FAMILIES)
def test_family_fast_apply_matches_matrix(family):
    fam = F.get_family(family)
    n = fam.valid_size(96)
    x = jax.random.normal(jax.random.PRNGKey(0), (5, n))
    c, ct = fam.matrices(n)
    np.testing.assert_allclose(np.asarray(fam.apply(x)),
                               np.asarray(x @ c), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fam.inverse(fam.apply(x))),
                               np.asarray(x), atol=1e-4)


@pytest.mark.parametrize("family", FAMILIES)
def test_family_riffle_and_init(family):
    fam = F.get_family(family)
    n = fam.valid_size(64)
    perm = fam.riffle(n)
    assert sorted(perm) == list(range(n))
    a, d = fam.init_diagonals(jax.random.PRNGKey(1), 3, n, 1.0, 0.05)
    assert a.shape == d.shape == (3, n)
    # identity + noise: both diagonals near 1
    assert abs(float(a.mean()) - 1.0) < 0.05
    assert abs(float(d.mean()) - 1.0) < 0.05


def test_valid_size_rules():
    assert F.get_family("acdc").valid_size(96) == 96
    assert F.get_family("circulant").valid_size(96) == 96
    assert F.get_family("hadamard").valid_size(96) == 128
    assert F.get_family("hadamard").valid_size(128) == 128


# ---------------------------------------------------------------------------
# End-to-end parity: cascade + SELL dense-equivalent oracle per family.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("method", ["matmul", "fft", "pallas"])
def test_cascade_methods_agree_per_family(family, method):
    """All three backends compute the same cascade for every family
    (matmul is the explicit-matrix oracle)."""
    n, k = 128, 3
    oracle = A.ACDCConfig(n=n, k=k, relu=True, permute=True, bias=True,
                          method="matmul", family=family)
    cfg = dataclasses.replace(oracle, method=method)
    p = A.init_acdc_params(jax.random.PRNGKey(2), oracle)
    x = jax.random.normal(jax.random.PRNGKey(3), (6, n))
    np.testing.assert_allclose(
        np.asarray(A.acdc_cascade(p, x, cfg)),
        np.asarray(A.acdc_cascade(p, x, oracle)),
        atol=2e-4, rtol=1e-3, err_msg=f"{family}/{method}")


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("method", ["matmul", "pallas"])
def test_sell_dense_equivalent_oracle_per_family(family, method):
    """kind='acdc' under any family is linear (no ReLU): applying the
    SELL must equal multiplying by its materialized dense equivalent,
    including the rectangular pad/truncate path."""
    cfg = S.SellConfig(kind="acdc", n_in=40, n_out=72, k=2, permute=True,
                       bias=False, method=method, transform=family,
                       lane_multiple=1)
    p = S.init_sell_params(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 40))
    w = S.sell_dense_equivalent(p, cfg)
    assert w.shape == (40, 72)
    np.testing.assert_allclose(
        np.asarray(S.structured_linear(p, x, cfg)),
        np.asarray(x @ w), atol=1e-4, err_msg=f"{family}/{method}")


def test_sell_hadamard_pads_to_pow2():
    cfg = S.SellConfig(kind="acdc", n_in=40, n_out=72, k=2,
                       transform="hadamard", lane_multiple=1)
    assert cfg.n_op == 128  # max(40, 72) -> next pow2
    cfg128 = S.SellConfig(kind="acdc", n_in=40, n_out=72, k=2,
                          transform="hadamard", lane_multiple=128)
    assert cfg128.n_op == 128


def test_with_sell_helper_validates_transform():
    from repro.configs import registry
    cfg = registry.get_smoke_config("qwen3_1_7b")
    out = registry.with_sell(cfg, "acdc", method="pallas",
                             transform="circulant")
    assert (out.sell_kind, out.sell_method, out.sell_transform) == \
        ("acdc", "pallas", "circulant")
    assert registry.with_sell(cfg, "dense", transform="whatever") is cfg
    with pytest.raises(ValueError, match="unknown transform family"):
        registry.with_sell(cfg, "acdc", transform="wavelet")


@pytest.mark.slow
@pytest.mark.parametrize("family", ["circulant", "hadamard"])
def test_engine_serves_every_family(family):
    """The continuous-batching engine runs end to end with non-DCT
    families on the fused Pallas path (the acceptance bar for the
    pluggable-transform refactor)."""
    from repro.configs import registry
    from repro.models import get_model
    from repro.serving import Engine, Request

    cfg = registry.get_smoke_config("qwen3_1_7b")
    cfg = registry.with_sell(cfg, "acdc", method="pallas",
                             transform=family)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.RandomState(11)
    reqs = [Request(rid=i,
                    prompt=rs.randint(0, cfg.vocab_size,
                                      size=rs.randint(4, 12)).tolist(),
                    max_new_tokens=6)
            for i in range(3)]
    eng = Engine(model, cfg, params, n_slots=2, max_len=20,
                 max_prompt_len=12)
    eng.run(reqs, max_ticks=300)
    for r in reqs:
        assert len(r.generated) > 0
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


# ---------------------------------------------------------------------------
# Bit-identity pins: family='acdc' IS the pre-registry code path.
# ---------------------------------------------------------------------------

def _goldens():
    with open(GOLDENS) as f:
        return json.load(f)


def test_goldens_backend_matches():
    g = _goldens()
    if g["backend"] != jax.default_backend():
        pytest.skip(f"goldens captured on {g['backend']}, running on "
                    f"{jax.default_backend()}")


def test_acdc_cascade_vjp_bit_identical_to_goldens():
    g = _goldens()
    if g["backend"] != jax.default_backend():
        pytest.skip("backend mismatch")
    from repro.kernels import ops

    n, k, m = 128, 3, 8
    r = jax.random.PRNGKey(41)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    b = 0.05 * jax.random.normal(jax.random.fold_in(r, 3), (k, n))
    gc = jax.random.normal(jax.random.fold_in(r, 4), (m, n))
    y, vjp = jax.vjp(
        lambda x, a, d, b: ops.acdc_cascade_op(x, a, d, b, relu=True,
                                               permute=True), x, a, d, b)
    dx, da, dd, db = vjp(gc)

    for name, arr in [("y", y), ("dx", dx), ("da", da), ("dd", dd),
                      ("db", db)]:
        flat = np.asarray(arr, np.float32).ravel()
        want = g["cascade_vjp"][name]
        got_bits = [int(w) for w in flat[:8].view(np.uint32)]
        assert got_bits == want["head_bits"], \
            f"{name}: fused-cascade VJP drifted bitwise from the " \
            f"pre-registry goldens"
        assert float(np.float64(flat).sum()) == want["checksum"], name


@pytest.mark.slow
def test_acdc_engine_streams_bit_identical_to_goldens():
    g = _goldens()
    if g["backend"] != jax.default_backend():
        pytest.skip("backend mismatch")
    from repro.configs import registry
    from repro.models import get_model
    from repro.serving import Engine, Request

    cfg = registry.get_smoke_config("qwen3_1_7b")
    cfg = registry.with_sell(cfg, "acdc", method="pallas")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.RandomState(7)
    reqs = [Request(rid=i,
                    prompt=rs.randint(0, cfg.vocab_size,
                                      size=rs.randint(4, 12)).tolist(),
                    max_new_tokens=8)
            for i in range(5)]
    assert [r.prompt for r in reqs] == g["engine"]["prompts"]
    eng = Engine(model, cfg, params, n_slots=2, max_len=24,
                 max_prompt_len=12)
    eng.run(reqs, max_ticks=400)
    got = [list(map(int, r.generated)) for r in reqs]
    assert got == g["engine"]["generated"], \
        "greedy engine streams drifted from the pre-registry goldens"


# ---------------------------------------------------------------------------
# Autotune cache: family keying + legacy migration.
# ---------------------------------------------------------------------------

def test_autotune_key_migration_appends_acdc():
    from repro.kernels import autotune as at
    legacy = "fwd|512|1|float32|False|False"
    assert at._key_from_str(legacy) == \
        ("fwd", 512, 1, "float32", False, False, "acdc")
    modern = "cascade_bwd|256|3|bfloat16|True|True|circulant"
    key = at._key_from_str(modern)
    assert key == ("cascade_bwd", 256, 3, "bfloat16", True, True,
                   "circulant")
    assert at._key_from_str(at._key_str(key)) == key


def test_autotune_persistent_migration_isolates_families(tmp_path,
                                                         monkeypatch):
    """A pre-family on-disk cache entry must surface as 'acdc' only — a
    circulant run may never inherit a DCT-swept block size."""
    from repro.kernels import autotune as at

    path = tmp_path / "autotune_cache.json"
    path.write_text(json.dumps({
        "backend": jax.default_backend(),
        "entries": {"fwd|512|1|float32|False|False": 64},
    }))
    monkeypatch.setenv(at.CACHE_ENV + "_PATH", str(path))
    monkeypatch.setattr(at, "_PERSIST_LOADED", False)
    monkeypatch.setattr(at, "_CACHE", {})
    at._load_persistent()
    acdc_key = ("fwd", 512, 1, "float32", False, False, "acdc")
    circ_key = ("fwd", 512, 1, "float32", False, False, "circulant")
    assert at._CACHE[acdc_key] == 64
    assert circ_key not in at._CACHE
