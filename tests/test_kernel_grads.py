"""Gradient parity of the fused Pallas backward + cascade fusion vs jnp
oracles (interpret mode on CPU, compiled on TPU).

Coverage matrix from the fused-training-hot-path issue:

* fused backward vs ``jax.grad`` of the jnp reference across BOTH N
  regimes (<= and > ``MAX_FUSED_N``), with/without bias, fp32 and bf16;
* direct VJP outputs vs the four-matmul reference formulation;
* cascade-fused forward vs the ``acdc_cascade`` oracle with ReLU/riffle
  on and off, plus cascade-level gradient parity;
* reverse-sweep cascade backward vs the per-layer-scan oracle across
  {relu} x {riffle} x {fp32, bf16-with-fp32-masters} x ragged rows,
  with routing assertions (in-budget -> reverse sweep, over-budget ->
  scan fallback, gradients unchanged either way);
* the model zoo's ``linear_apply`` projections and the ``dist/steps.py``
  train step pick the pallas path up unchanged (including the
  reverse-sweep backward in the train step's VJP).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import acdc as A
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import ops, ref

SMALL_N = 256                       # single fused kernel regime
BIG_N = fused_mod.MAX_FUSED_N * 2   # two-call scaled_matmul regime


def _layer(n, dtype=jnp.float32, seed=0):
    r = jax.random.PRNGKey(seed)
    m = 4 if n > fused_mod.MAX_FUSED_N else 16
    x = jax.random.normal(r, (m, n), dtype)
    # diagonals stay fp32 masters — the kernels take them uncast
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    b = 0.1 * jax.random.normal(jax.random.fold_in(r, 3), (n,))
    return x, a, d, b


def _grad_tol(dtype, n):
    return 1e-4 * np.sqrt(n / 128) if dtype == jnp.float32 else 5e-2


@pytest.mark.parametrize("n", [SMALL_N, BIG_N])
@pytest.mark.parametrize("bias", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_backward_matches_autodiff_of_oracle(n, bias, dtype):
    x, a, d, b = _layer(n, dtype)
    args = (x, a, d, b) if bias else (x, a, d)
    argnums = tuple(range(len(args)))

    def lk(*args):
        return jnp.sum(jnp.tanh(ops.acdc_fused_op(*args).astype(jnp.float32)))

    def lr(*args):
        return jnp.sum(jnp.tanh(ref.acdc_fused_ref(*args).astype(jnp.float32)))

    gk = jax.grad(lk, argnums=argnums)(*args)
    gr = jax.grad(lr, argnums=argnums)(*args)
    for name, got, want in zip("xadb", gk, gr):
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=_grad_tol(dtype, n), rtol=2e-2 if dtype == jnp.bfloat16
            else 1e-3, err_msg=f"{name} n={n}")


@pytest.mark.parametrize("n", [128, SMALL_N])
def test_vjp_outputs_match_four_matmul_reference(n):
    """The fused kernel's raw VJP cotangents equal the eq. 10-14 reference
    (the four-matmul formulation it replaced), not just chained grads."""
    x, a, d, b = _layer(n, seed=n)
    g = jax.random.normal(jax.random.PRNGKey(99), x.shape)
    _, vjp = jax.vjp(ops.acdc_fused, x, a, d, b)
    dx, da, dd, db = vjp(g)
    rx, ra, rd, rb = ref.acdc_bwd_ref(x, a, d, g)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), atol=1e-4)
    np.testing.assert_allclose(np.asarray(da), np.asarray(ra), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rb), atol=1e-4,
                               rtol=1e-4)


def test_mixed_dtype_bias_cotangent():
    """bf16 diagonals with an fp32 bias (reachable now that the pallas
    path takes master params uncast): each cotangent must match its own
    primal's dtype, not d's."""
    n = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n), jnp.bfloat16)
    a = jnp.ones((n,), jnp.bfloat16)
    d = jnp.ones((n,), jnp.bfloat16)
    b = jnp.zeros((n,), jnp.float32)
    g = jax.grad(lambda x, a, d, b: jnp.sum(
        ops.acdc_fused_op(x, a, d, b).astype(jnp.float32)),
        argnums=(0, 1, 2, 3))(x, a, d, b)
    assert g[0].dtype == jnp.bfloat16
    assert g[1].dtype == jnp.bfloat16
    assert g[3].dtype == jnp.float32


def test_fused_backward_ragged_rows_ignore_padding():
    """Row counts that don't divide the block size: zero-padded rows must
    contribute nothing to the diagonal reductions."""
    n = 128
    r = jax.random.PRNGKey(3)
    x = jax.random.normal(r, (13, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    g = jax.random.normal(jax.random.fold_in(r, 4), (13, n))
    _, vjp = jax.vjp(ops.acdc_fused_nobias, x, a, d)
    dx, da, dd = vjp(g)
    rx, ra, rd, _ = ref.acdc_bwd_ref(x, a, d, g)
    np.testing.assert_allclose(np.asarray(da), np.asarray(ra), atol=1e-4)
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd), atol=1e-4)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), atol=1e-4)


def test_nd_batch_gradients():
    """ND inputs flatten through the VJP and come back in shape."""
    n = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, n))
    a = jnp.ones((n,))
    d = 1.5 * jnp.ones((n,))

    gk = jax.grad(lambda x: jnp.sum(ops.acdc_fused_op(x, a, d) ** 2))(x)
    gr = jax.grad(lambda x: jnp.sum(ref.acdc_fused_ref(x, a, d) ** 2))(x)
    assert gk.shape == x.shape
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-4)


# ---------------------------------------------------------------------------
# Whole-cascade fusion.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("permute", [False, True])
@pytest.mark.parametrize("bias", [False, True])
def test_cascade_fused_forward_vs_oracle(relu, permute, bias):
    n, k = 128, 3
    kw = dict(n=n, k=k, relu=relu, permute=permute, bias=bias)
    cfg_p = A.ACDCConfig(method="pallas", **kw)
    cfg_o = A.ACDCConfig(method="matmul", **kw)
    p = A.init_acdc_params(jax.random.PRNGKey(11), cfg_p)
    if bias:
        p["bias"] = p["bias"] + 0.05  # nonzero so the bias path is live
    x = jax.random.normal(jax.random.PRNGKey(1), (10, n))
    got = A.acdc_cascade(p, x, cfg_p)
    want = A.acdc_cascade(p, x, cfg_o)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("relu,permute,bias", [
    (False, False, False), (True, True, True), (True, False, False),
])
def test_cascade_fused_gradients_vs_oracle(relu, permute, bias):
    n, k = 128, 3
    kw = dict(n=n, k=k, relu=relu, permute=permute, bias=bias)
    cfg_p = A.ACDCConfig(method="pallas", **kw)
    cfg_o = A.ACDCConfig(method="matmul", **kw)
    p = A.init_acdc_params(jax.random.PRNGKey(13), cfg_p)
    if bias:
        p["bias"] = p["bias"] + 0.05
    x = jax.random.normal(jax.random.PRNGKey(2), (8, n))

    def loss(cfg):
        return lambda p, x: jnp.sum(jnp.tanh(A.acdc_cascade(p, x, cfg)))

    gp, gxp = jax.grad(loss(cfg_p), argnums=(0, 1))(p, x)
    go, gxo = jax.grad(loss(cfg_o), argnums=(0, 1))(p, x)
    np.testing.assert_allclose(np.asarray(gxp), np.asarray(gxo), atol=2e-4,
                               rtol=1e-3)
    for key in gp:
        np.testing.assert_allclose(
            np.asarray(gp[key]), np.asarray(go[key]), atol=2e-4, rtol=1e-3,
            err_msg=key)


def test_cascade_fused_bf16_activation_fp32_masters():
    """bf16 residual stream with fp32 master diagonals: output dtype
    follows the activation, gradients follow the parameters."""
    n, k = 128, 2
    cfg = A.ACDCConfig(n=n, k=k, relu=True, bias=False, method="pallas")
    p = A.init_acdc_params(jax.random.PRNGKey(5), cfg)  # fp32 masters
    x = jax.random.normal(jax.random.PRNGKey(6), (8, n), jnp.bfloat16)
    y = A.acdc_cascade(p, x, cfg)
    assert y.dtype == jnp.bfloat16
    g = jax.grad(lambda p: jnp.sum(
        A.acdc_cascade(p, x, cfg).astype(jnp.float32)))(p)
    assert g["a"].dtype == jnp.float32
    cfg_o = A.ACDCConfig(n=n, k=k, relu=True, bias=False, method="matmul")
    g_o = jax.grad(lambda p: jnp.sum(
        A.acdc_cascade(p, x, cfg_o).astype(jnp.float32)))(p)
    np.testing.assert_allclose(np.asarray(g["a"]), np.asarray(g_o["a"]),
                               atol=0.3, rtol=0.1)


def test_cascade_fallback_beyond_vmem_budget():
    """N above MAX_FUSED_N: the cascade op must fall back to the
    per-layer path and still match the oracle (fwd + grads)."""
    n, k = fused_mod.MAX_FUSED_N * 2, 2
    cfg_p = A.ACDCConfig(n=n, k=k, relu=True, bias=False, method="pallas")
    cfg_o = A.ACDCConfig(n=n, k=k, relu=True, bias=False, method="fft")
    p = A.init_acdc_params(jax.random.PRNGKey(7), cfg_p)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, n))
    np.testing.assert_allclose(
        np.asarray(A.acdc_cascade(p, x, cfg_p)),
        np.asarray(A.acdc_cascade(p, x, cfg_o)), atol=2e-3, rtol=1e-3)
    gp = jax.grad(lambda p: jnp.sum(jnp.tanh(A.acdc_cascade(p, x, cfg_p))))(p)
    go = jax.grad(lambda p: jnp.sum(jnp.tanh(A.acdc_cascade(p, x, cfg_o))))(p)
    np.testing.assert_allclose(np.asarray(gp["d"]), np.asarray(go["d"]),
                               atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Reverse-sweep cascade backward (kernels/acdc_cascade_bwd.py).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("permute", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows", [16, 13])  # block-aligned and ragged
def test_reverse_sweep_backward_matches_scan_oracle(relu, permute, dtype,
                                                    rows):
    """The reverse-sweep kernel's raw cotangents equal the per-layer-scan
    path it replaced (ops._cascade_bwd_core), for every interleave combo,
    fp32 and bf16-with-fp32-masters, aligned and ragged row counts."""
    n, k = 128, 3
    r = jax.random.PRNGKey(17)
    x = jax.random.normal(r, (rows, n), dtype)
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    b = 0.05 + 0.1 * jax.random.normal(jax.random.fold_in(r, 3), (k, n))
    g = jax.random.normal(jax.random.fold_in(r, 4), (rows, n), dtype)

    got = ops._cascade_bwd_fused(relu, permute, x, a, d, b, g)
    want = ops._cascade_bwd_core(relu, permute, x, a, d, b, g)
    # bf16: the scan oracle casts the rematerialized activations back to
    # bf16 between layers while the reverse sweep (like the fused
    # forward) keeps them fp32 on-chip — compare loosely.
    atol = 2e-4 if dtype == jnp.float32 else 0.15
    rtol = 1e-3 if dtype == jnp.float32 else 0.1
    for name, gv, wv in zip(("dx", "da", "dd", "db"), got, want):
        assert gv.dtype == wv.dtype, name
        np.testing.assert_allclose(
            np.asarray(gv, np.float32), np.asarray(wv, np.float32),
            atol=atol, rtol=rtol, err_msg=f"{name} relu={relu} "
            f"permute={permute} rows={rows}")


def test_reverse_sweep_backward_nobias_matches_scan_oracle():
    n, k = 128, 4
    r = jax.random.PRNGKey(23)
    x = jax.random.normal(r, (10, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    g = jax.random.normal(jax.random.fold_in(r, 3), (10, n))
    got = ops._cascade_bwd_fused(True, True, x, a, d, None, g)
    want = ops._cascade_bwd_core(True, True, x, a, d, None, g)
    assert len(got) == 3  # no dbias entry for the bias-free primitive
    for name, gv, wv in zip(("dx", "da", "dd"), got, want):
        np.testing.assert_allclose(np.asarray(gv), np.asarray(wv),
                                   atol=2e-4, rtol=1e-3, err_msg=name)


def test_cascade_backward_routes_reverse_sweep_in_budget():
    """Fused-regime cascades must take the reverse-sweep VJP (the CI
    dispatch-regression gate counts exactly this)."""
    n, k = 128, 3
    cfg = A.ACDCConfig(n=n, k=k, relu=True, permute=True, bias=True,
                       method="pallas")
    p = A.init_acdc_params(jax.random.PRNGKey(29), cfg)
    x = jax.random.normal(jax.random.PRNGKey(30), (8, n))
    before = dict(ops.CASCADE_BWD_DISPATCHES)
    jax.grad(lambda p: jnp.sum(jnp.tanh(A.acdc_cascade(p, x, cfg))))(p)
    assert ops.CASCADE_BWD_DISPATCHES["reverse_sweep"] == \
        before["reverse_sweep"] + 1
    assert ops.CASCADE_BWD_DISPATCHES["per_layer_scan"] == \
        before["per_layer_scan"]


def test_cascade_backward_over_budget_falls_back_to_scan(monkeypatch):
    """When the stash-inclusive backward budget doesn't fit, the forward
    can stay fused while the backward routes to the per-layer scan — and
    gradients must be unchanged."""
    from repro.kernels import acdc_cascade_bwd as cbwd_mod

    n, k = 128, 3
    cfg = A.ACDCConfig(n=n, k=k, relu=True, permute=True, bias=False,
                       method="pallas")
    p = A.init_acdc_params(jax.random.PRNGKey(31), cfg)
    x = jax.random.normal(jax.random.PRNGKey(32), (8, n))

    def loss(p):
        return jnp.sum(jnp.tanh(A.acdc_cascade(p, x, cfg)))

    want = jax.grad(loss)(p)
    monkeypatch.setattr(cbwd_mod, "pick_bm",
                        lambda *a, **kw: None)  # force over-budget
    before = dict(ops.CASCADE_BWD_DISPATCHES)
    got = jax.grad(loss)(p)
    assert ops.CASCADE_BWD_DISPATCHES["per_layer_scan"] == \
        before["per_layer_scan"] + 1
    for key in want:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   atol=2e-4, rtol=1e-3, err_msg=key)


def test_reverse_sweep_rejects_k1():
    from repro.kernels import acdc_cascade_bwd as cbwd_mod
    from repro.core import transforms

    n = 128
    c = transforms.dct_matrix(n)
    ct = transforms.idct_matrix(n)
    with pytest.raises(ValueError, match="K >= 2"):
        cbwd_mod.acdc_cascade_bwd_pallas(
            jnp.ones((8, n)), jnp.ones((8, n)), jnp.ones((1, n)),
            jnp.ones((1, n)), None, c, ct, None, interpret=True)


def test_reverse_sweep_budget_shrinks_block_with_depth():
    """pick_bm must account for the (K-1)-deep VMEM stash: deep riffled
    cascades at MAX_FUSED_N get a smaller block or fall back entirely."""
    from repro.kernels import acdc_cascade_bwd as cbwd_mod

    shallow = cbwd_mod.pick_bm(256, 2, permute=True, bias=True)
    deep = cbwd_mod.pick_bm(fused_mod.MAX_FUSED_N, 4, permute=True,
                            bias=True)
    assert shallow is not None
    assert deep is None or deep < shallow
    assert cbwd_mod.pick_bm(fused_mod.MAX_FUSED_N * 2, 2, permute=False,
                            bias=False) is None


def test_cascade_k1_degenerates_to_single_layer():
    n = 128
    cfg = A.ACDCConfig(n=n, k=1, bias=True, method="pallas")
    p = A.init_acdc_params(jax.random.PRNGKey(9), cfg)
    x = jax.random.normal(jax.random.PRNGKey(10), (6, n))
    got = A.acdc_cascade(p, x, cfg)
    want = ref.acdc_fused_ref(x, p["a"][0], p["d"][0], p["bias"][0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# Integration: model zoo projections + dist train step.
# ---------------------------------------------------------------------------

def test_linear_apply_pallas_matches_matmul_method():
    """The zoo's projection factory picks up the fused cascade unchanged:
    same params, same output, only the method differs."""
    from repro.configs import registry
    from repro.models import linear as linear_mod

    cfg = registry.get_smoke_config("qwen3_1_7b")
    cfg_p = dataclasses.replace(cfg, sell_kind="acdc", sell_method="pallas")
    cfg_m = dataclasses.replace(cfg, sell_kind="acdc", sell_method="matmul")
    n_in = n_out = 256
    params = linear_mod.linear_init(jax.random.PRNGKey(0), n_in, n_out,
                                    cfg_p, role="mlp_in")
    assert "sell" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, n_in))
    yp = linear_mod.linear_apply(params, x, n_in, n_out, cfg_p, "mlp_in")
    ym = linear_mod.linear_apply(params, x, n_in, n_out, cfg_m, "mlp_in")
    assert yp.shape == (2, 4, n_out)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(ym), atol=2e-4,
                               rtol=1e-3)


@pytest.mark.slow
def test_train_step_runs_with_pallas_sell():
    """dist/steps.make_train_step trains through the fused cascade VJP —
    and its backward picks up the reverse-sweep kernel (the smoke SELL
    cascades are K>=2 and well inside the VMEM budget, so a per-layer
    routing here would be a dispatch regression)."""
    from repro.configs import registry
    from repro.data import DataConfig, SyntheticLM
    from repro.dist import steps as steps_mod
    from repro.models import get_model
    from repro.optim import OptimizerConfig, constant_schedule, make_optimizer

    cfg = registry.get_smoke_config("qwen3_1_7b")
    cfg = dataclasses.replace(cfg, sell_kind="acdc", sell_method="pallas")
    model = get_model(cfg)
    opt = make_optimizer(OptimizerConfig(lr=1e-3, weight_decay=0.0),
                         constant_schedule(1e-3))
    step = jax.jit(steps_mod.make_train_step(model, cfg, opt))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=2))
    state = steps_mod.init_state(model, cfg, opt, jax.random.PRNGKey(0))
    before = dict(ops.CASCADE_BWD_DISPATCHES)
    state, m0 = step(state, data.batch_at(0))
    state, m1 = step(state, data.batch_at(1))
    assert np.isfinite(float(m0["loss"])) and np.isfinite(float(m1["loss"]))
    assert int(state["step"]) == 2
    assert ops.CASCADE_BWD_DISPATCHES["reverse_sweep"] > \
        before["reverse_sweep"]
    assert ops.CASCADE_BWD_DISPATCHES["per_layer_scan"] == \
        before["per_layer_scan"]


# ---------------------------------------------------------------------------
# Transform-family parity (core/families.py): the fused kernel stack is
# family-generic — every registered real-orthonormal family must produce
# the same forward and cotangents through the fused whole-cascade path as
# through the per-layer jnp scan.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["acdc", "circulant", "hadamard"])
@pytest.mark.parametrize("bias", [True, False])
def test_cascade_grads_fused_matches_scan_per_family(family, bias):
    n, k, m = 128, 3, 9
    r = jax.random.PRNGKey(53)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    b = 0.05 * jax.random.normal(jax.random.fold_in(r, 3), (k, n)) \
        if bias else None
    g = jax.random.normal(jax.random.fold_in(r, 4), (m, n))

    def fused(x, a, d, b):
        return ops.acdc_cascade_op(x, a, d, b, relu=True, permute=True,
                                   family=family)

    def scan(x, a, d, b):
        return ops._cascade_per_layer(x, a, d, b, True, True,
                                      family=family)

    if bias:
        y_f, vjp_f = jax.vjp(fused, x, a, d, b)
        y_s, vjp_s = jax.vjp(scan, x, a, d, b)
    else:
        y_f, vjp_f = jax.vjp(lambda x, a, d: fused(x, a, d, None), x, a, d)
        y_s, vjp_s = jax.vjp(lambda x, a, d: scan(x, a, d, None), x, a, d)
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_s),
                               atol=2e-4, rtol=1e-3, err_msg=family)
    for name, gf, gs in zip(("dx", "da", "dd", "db"),
                            vjp_f(g), vjp_s(g)):
        np.testing.assert_allclose(
            np.asarray(gf), np.asarray(gs), atol=2e-4, rtol=1e-3,
            err_msg=f"{family} {name} bias={bias}")


@pytest.mark.parametrize("family", ["circulant", "hadamard"])
def test_reverse_sweep_backward_per_family(family):
    """The reverse-sweep kernel's raw cotangents match the per-layer-scan
    core for the non-DCT families too (same kernel body, different C)."""
    n, k, m = 128, 3, 10
    r = jax.random.PRNGKey(59)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    b = 0.05 * jax.random.normal(jax.random.fold_in(r, 3), (k, n))
    g = jax.random.normal(jax.random.fold_in(r, 4), (m, n))
    got = ops._cascade_bwd_fused(True, True, x, a, d, b, g, family=family)
    want = ops._cascade_bwd_core(True, True, x, a, d, b, g, family=family)
    for name, gv, wv in zip(("dx", "da", "dd", "db"), got, want):
        np.testing.assert_allclose(np.asarray(gv), np.asarray(wv),
                                   atol=2e-4, rtol=1e-3,
                                   err_msg=f"{family} {name}")
