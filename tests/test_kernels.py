"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes, plus custom-VJP correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import transforms as T
from repro.kernels import ops, ref
from repro.kernels import acdc_fused as fused_mod
from repro.kernels import scaled_matmul as smm_mod


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


SHAPES = [(4, 128), (17, 128), (128, 256), (100, 512), (256, 1024)]


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_acdc_fused_vs_oracle(m, n, dtype):
    r = jax.random.PRNGKey(m * 1000 + n)
    x = jax.random.normal(r, (m, n), dtype)
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,), dtype)
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,), dtype)
    b = 0.1 * jax.random.normal(jax.random.fold_in(r, 3), (n,), dtype)
    got = ops.acdc_fused_op(x, a, d, b)
    want = ref.acdc_fused_ref(x, a, d, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype) * np.sqrt(n), rtol=1e-2)


def test_acdc_fused_two_call_path():
    """N > MAX_FUSED_N exercises the chained scaled-matmul implementation."""
    n = fused_mod.MAX_FUSED_N * 2
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.PRNGKey(2), (n,))
    b = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (n,))
    got = ops.acdc_fused_op(x, a, d, b)
    want = ref.acdc_fused_ref(x, a, d, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_acdc_fused_no_bias_and_nd_batch():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 128))
    a = jnp.ones((128,))
    d = jnp.ones((128,))
    got = ops.acdc_fused_op(x, a, d, None)
    want = ref.acdc_fused_ref(x, a, d, None)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_acdc_custom_vjp_matches_autodiff_of_oracle():
    m, n = 16, 256
    r = jax.random.PRNGKey(9)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    b = 0.1 * jax.random.normal(jax.random.fold_in(r, 3), (n,))

    def lk(x, a, d, b):
        return jnp.sum(jnp.tanh(ops.acdc_fused_op(x, a, d, b)))

    def lr(x, a, d, b):
        return jnp.sum(jnp.tanh(ref.acdc_fused_ref(x, a, d, b)))

    gk = jax.grad(lk, argnums=(0, 1, 2, 3))(x, a, d, b)
    gr = jax.grad(lr, argnums=(0, 1, 2, 3))(x, a, d, b)
    for name, k, r_ in zip("xadb", gk, gr):
        np.testing.assert_allclose(np.asarray(k), np.asarray(r_),
                                   atol=2e-4, rtol=1e-3, err_msg=name)


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (64, 256, 512),
                                   (100, 300, 200), (33, 65, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scaled_matmul_vs_oracle(m, k, n, dtype):
    r = jax.random.PRNGKey(m + k + n)
    x = jax.random.normal(r, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(r, 1), (k, n), dtype)
    pre = jax.random.normal(jax.random.fold_in(r, 2), (k,), dtype)
    post = jax.random.normal(jax.random.fold_in(r, 3), (n,), dtype)
    bias = jax.random.normal(jax.random.fold_in(r, 4), (n,), dtype)
    got = ops.scaled_matmul(x, w, pre, post, bias)
    want = ref.scaled_matmul_ref(x, w, pre, post, bias)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype) * np.sqrt(k) * 4, rtol=2e-2)


@pytest.mark.parametrize("opts", [
    dict(), dict(pre=True), dict(post=True), dict(bias=True),
    dict(pre=True, post=True, bias=True),
])
def test_scaled_matmul_optional_operands(opts):
    m, k, n = 16, 64, 96
    r = jax.random.PRNGKey(0)
    x = jax.random.normal(r, (m, k))
    w = jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    pre = jax.random.normal(jax.random.fold_in(r, 2), (k,)) if opts.get("pre") else None
    post = jax.random.normal(jax.random.fold_in(r, 3), (n,)) if opts.get("post") else None
    bias = jax.random.normal(jax.random.fold_in(r, 4), (n,)) if opts.get("bias") else None
    got = ops.scaled_matmul(x, w, pre, post, bias)
    want = ref.scaled_matmul_ref(x, w, pre, post, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


@given(st.integers(1, 64), st.sampled_from([128, 256, 384]),
       st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_acdc_kernel_property_sweep(m, n, seed):
    r = jax.random.PRNGKey(seed)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.05 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.05 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    got = ops.acdc_fused_op(x, a, d, None)
    want = ref.acdc_fused_ref(x, a, d, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("relu,permute", [(False, False), (True, True)])
def test_acdc_cascade_op_vs_layered_ref(relu, permute):
    """ops.acdc_cascade_op == K chained ref layers with jnp interleaves."""
    n, k, m = 128, 4, 12
    r = jax.random.PRNGKey(21)
    x = jax.random.normal(r, (m, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (k, n))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (k, n))
    b = 0.1 * jax.random.normal(jax.random.fold_in(r, 3), (k, n))
    got = ops.acdc_cascade_op(x, a, d, b, relu=relu, permute=permute)

    perm = jnp.asarray(T.make_riffle(n))
    h = x
    for i in range(k):
        h = ref.acdc_fused_ref(h, a[i], d[i], b[i])
        if i < k - 1:
            if relu:
                h = jnp.maximum(h, 0)
            if permute:
                h = h[..., perm]
    np.testing.assert_allclose(np.asarray(got), np.asarray(h),
                               atol=5e-4, rtol=1e-3)


def test_cascade_vmem_budget_gate():
    """fits_vmem: small cascades fuse; N beyond MAX_FUSED_N never does,
    and the riffle's third transform matrix tightens the budget."""
    from repro.kernels import acdc_cascade_fused as cascade_mod
    assert cascade_mod.fits_vmem(256, 8, permute=True, bias=True)
    assert not cascade_mod.fits_vmem(
        fused_mod.MAX_FUSED_N * 2, 2, permute=False, bias=False)
    assert (cascade_mod.cascade_vmem_bytes(1024, 4, permute=True, bias=True)
            > cascade_mod.cascade_vmem_bytes(1024, 4, permute=False,
                                             bias=True))


def test_kernel_agrees_with_core_acdc():
    """core.acdc(method='pallas') routes through the kernel and matches
    the fft/matmul methods."""
    from repro.core import acdc as A
    n = 256
    r = jax.random.PRNGKey(4)
    x = jax.random.normal(r, (6, n))
    a = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 1), (n,))
    d = 1 + 0.1 * jax.random.normal(jax.random.fold_in(r, 2), (n,))
    yp = A.acdc(x, a, d, method="pallas")
    yf = A.acdc(x, a, d, method="fft")
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yf),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Block-size autotuning (first-call sweep, memoized; fixed fallback on CPU).
# ---------------------------------------------------------------------------

def test_autotune_cpu_fallback_keeps_fixed_constants():
    """Off-device the sweep is skipped: the pre-autotune constants come
    back (256 fwd / 128 bwd / budget-derived cascade) and are memoized."""
    from repro.kernels import acdc_bwd as bwd_mod
    from repro.kernels import acdc_cascade_fused as cascade_mod
    from repro.kernels import autotune
    assert jax.default_backend() != "tpu"  # this suite runs on CPU
    assert autotune.autotuned_bm("fwd", 512) == fused_mod.DEFAULT_BM
    assert autotune.autotuned_bm("bwd", 512) == bwd_mod.DEFAULT_BM
    assert autotune.autotuned_bm(
        "cascade", 1024, 4, bias=True, permute=True) == cascade_mod.pick_bm(
            1024, 4, permute=True, bias=True)
    key = ("fwd", 512, 1, "float32", False, False, "acdc")
    assert autotune._CACHE[key] == fused_mod.DEFAULT_BM


def test_autotune_sweep_picks_fastest_candidate():
    """The sweep returns the argmin of the injected timer and only ever
    considers candidates inside the cascade VMEM budget."""
    from repro.kernels import autotune

    fake = {64: 3.0, 128: 1.0, 256: 2.0}
    bm = autotune.sweep("fwd", 128, interpret=True,
                        timer=lambda thunk: fake[thunk.bm])
    assert bm == 128
    # riffled N=1024 cascades exceed the budget at bm=128/256: only 64
    # may be timed, whatever the timer says
    cands = autotune._candidates("cascade", 1024, 4, bias=True, permute=True)
    assert cands == [64]


def test_autotune_sweep_runs_kernels_in_interpret_mode():
    """End-to-end: the default timer path dispatches every direction's
    kernel (interpret mode) and returns a legal candidate."""
    from repro.kernels import autotune
    for direction in ("fwd", "bwd", "cascade", "cascade_bwd"):
        bm = autotune.sweep(direction, 128, 2, bias=True, interpret=True,
                            timer=None)
        assert bm in autotune.CANDIDATE_BMS


def test_autotune_cascade_bwd_fallback_is_budget_derived():
    """Off-device the cascade_bwd direction answers with the reverse-sweep
    module's own pick_bm (stash-inclusive budget), not the forward's."""
    from repro.kernels import acdc_cascade_bwd as cbwd_mod
    from repro.kernels import autotune
    got = autotune.autotuned_bm("cascade_bwd", 256, 4, bias=True,
                                permute=True)
    assert got == cbwd_mod.pick_bm(256, 4, permute=True, bias=True)


def test_autotune_persistent_cache_roundtrip(tmp_path, monkeypatch):
    """Swept winners spill to JSON and reload in a fresh process-alike
    (cleared memo); entries from a different backend are ignored; the
    env kill-switch disables both directions."""
    from repro.kernels import autotune

    path = tmp_path / "autotune_cache.json"
    monkeypatch.setenv(autotune.CACHE_ENV + "_PATH", str(path))
    monkeypatch.setattr(autotune, "_backend", lambda: "tpu")
    monkeypatch.setattr(autotune, "sweep",
                        lambda *a, **kw: 64)  # pretend the device sweep ran
    monkeypatch.setattr(autotune, "_CACHE", {})
    monkeypatch.setattr(autotune, "_PERSIST_LOADED", False)

    assert autotune.autotuned_bm("cascade_bwd", 256, 4, bias=True) == 64
    assert path.exists()

    # fresh process: memo cleared, sweep would now answer differently —
    # the persisted winner must be preferred (no re-sweep).
    monkeypatch.setattr(autotune, "sweep", lambda *a, **kw: 128)
    monkeypatch.setattr(autotune, "_CACHE", {})
    monkeypatch.setattr(autotune, "_PERSIST_LOADED", False)
    assert autotune.autotuned_bm("cascade_bwd", 256, 4, bias=True) == 64

    # a different backend must NOT consume the file: non-TPU answers are
    # the budget-derived fallback, never the persisted TPU winner.
    from repro.kernels import acdc_cascade_bwd as cbwd_mod
    monkeypatch.setattr(autotune, "_backend", lambda: "gpu")
    monkeypatch.setattr(autotune, "_CACHE", {})
    monkeypatch.setattr(autotune, "_PERSIST_LOADED", False)
    fallback = cbwd_mod.pick_bm(256, 4, permute=False, bias=True)
    assert fallback != 64
    assert autotune.autotuned_bm("cascade_bwd", 256, 4, bias=True) == fallback

    # kill switch: no load, no save.
    monkeypatch.setenv(autotune.CACHE_ENV, "0")
    monkeypatch.setattr(autotune, "_backend", lambda: "tpu")
    monkeypatch.setattr(autotune, "sweep", lambda *a, **kw: 256)
    monkeypatch.setattr(autotune, "_CACHE", {})
    monkeypatch.setattr(autotune, "_PERSIST_LOADED", False)
    path.unlink()
    assert autotune.autotuned_bm("cascade_bwd", 256, 4, bias=True) == 256
    assert not path.exists()


def test_autotune_cpu_never_touches_persistent_cache(tmp_path, monkeypatch):
    """CPU fallback answers must neither read nor write the device cache
    (a persisted CPU constant would silently skip a real TPU sweep)."""
    from repro.kernels import autotune

    path = tmp_path / "autotune_cache.json"
    path.write_text('{"backend": "tpu", "entries": {"fwd|512|1|float32|'
                    'False|False": 32}}')
    monkeypatch.setenv(autotune.CACHE_ENV + "_PATH", str(path))
    monkeypatch.setattr(autotune, "_CACHE", {})
    monkeypatch.setattr(autotune, "_PERSIST_LOADED", False)
    assert jax.default_backend() != "tpu"
    assert autotune.autotuned_bm("fwd", 512) == fused_mod.DEFAULT_BM  # not 32


def test_autotune_sweep_executes_inside_jit_trace():
    """The sweep's only production call sites are first hit INSIDE a jit
    trace; the compile-time-eval operand build plus AOT-compiled kernel
    dispatch must execute concretely (timing real work) instead of being
    staged into the caller's jaxpr.  Covers every direction including the
    backward kernel's program_id/scratch machinery."""
    from repro.kernels import autotune

    seen = {}

    @jax.jit
    def traced(y):
        for direction in ("fwd", "bwd", "cascade"):
            seen[direction] = autotune.sweep(direction, 128, 2, bias=True,
                                             interpret=True, timer=None)
        return y

    traced(jnp.ones(()))
    for direction in ("fwd", "bwd", "cascade"):
        assert isinstance(seen[direction], int)
        assert seen[direction] in autotune.CANDIDATE_BMS
