"""Observability: metric registry semantics, span-trace well-formedness
under seeded chaos, and the engine's noop fast path.

Three layers of assertions:

* the metrics primitives (Counter/Gauge/Histogram, labels, snapshot/
  merge/Prometheus, the derived-gauge staleness fix, the dict shims);
* the tracer: contiguous per-request phase chains, exactly one terminal
  event per request, deterministic Chrome exports;
* the engine: obs OFF binds no tracer/exporter/tick hook (the documented
  noop path) and greedy token streams are identical with obs on and off;
  a seeded FaultPlan chaos run over a virtual clock yields a complete,
  well-formed, replay-deterministic trace covering every finish reason
  the run produced — including the engineered ``timeout``, ``rejected``
  and ``preempted_limit`` terminals.
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model
from repro.obs import Observability
from repro.obs.metrics import (CounterDict, JsonlExporter, Registry,
                               StatsView, merge_snapshots)
from repro.obs.prof import Prof, parse_tick_window
from repro.obs.trace import SpanTracer, instant_global, set_global_tracer
from repro.serving import Engine, FaultPlan, Request


class FakeClock:
    """Deterministic virtual clock (same shape as the resilience tests')."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# Metrics primitives.
# ---------------------------------------------------------------------------

def test_counter_gauge_basics_and_labels():
    reg = Registry()
    c = reg.counter("c_total", "a counter", labels=("route",))
    c.labels(route="a").inc()
    c.labels(route="a").inc(2)
    c.labels(route="b").inc()
    assert c.labels(route="a").value == 3
    assert c.labels(route="b").value == 1
    g = reg.gauge("g")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    # get-or-create: same name+kind returns the same family
    assert reg.counter("c_total", labels=("route",)) is c
    with pytest.raises(ValueError):
        reg.gauge("c_total")            # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("c_total")          # label mismatch
    with pytest.raises(ValueError):
        c.labels(wrong="a")             # undeclared label name


def test_histogram_percentile_within_one_bin_width():
    reg = Registry()
    h = reg.histogram("lat_seconds")
    rs = np.random.RandomState(0)
    vals = rs.lognormal(mean=-3.0, sigma=1.0, size=2000)
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(vals.sum())
    for q in (1.0, 25.0, 50.0, 90.0, 99.0):
        hp = h.percentile(q)
        lp = float(np.percentile(vals, q))
        assert abs(hp - lp) <= max(h.bin_width(hp), h.bin_width(lp)), (
            f"p{q}: {hp} vs {lp}")


def test_histogram_under_overflow_and_reset():
    reg = Registry()
    h = reg.histogram("h", lo=1e-3, hi=1e0)
    h.observe(1e-9)                     # underflow
    h.observe(1e9)                      # overflow
    assert h.count == 2
    assert h.percentile(0.0) == h.lo
    assert h.percentile(100.0) == h.hi
    assert h.bin_width(1e9) == float("inf")
    h.reset()
    assert h.count == 0 and h.sum == 0.0
    assert h.percentile(50.0) is None


def test_derived_gauge_never_stale():
    reg = Registry()
    acc = reg.counter("accepted_total")
    drf = reg.counter("drafted_total")
    reg.derived_gauge("rate", lambda: acc.value / drf.value
                      if drf.value else 0.0)
    assert reg.snapshot()["gauges"]["rate"][""] == 0.0
    drf.inc(4)
    acc.inc(1)
    assert reg.snapshot()["gauges"]["rate"][""] == 0.25
    drf.inc(4)                          # rate recomputes even though acc
    assert reg.snapshot()["gauges"]["rate"][""] == 0.125   # didn't move
    with pytest.raises(ValueError):
        reg.derived_gauge("accepted_total", lambda: 0.0)   # name clash


def test_snapshot_deterministic_and_merge():
    def build():
        reg = Registry()
        reg.counter("c", labels=("k",)).labels(k="x").inc(2)
        reg.gauge("g").set(3)
        h = reg.histogram("h")
        for v in (0.01, 0.1, 0.1):
            h.observe(v)
        return reg

    a, b = build(), build()
    sa, sb = a.snapshot(), b.snapshot()
    assert json.dumps(sa, sort_keys=True) == json.dumps(sb, sort_keys=True)
    m = merge_snapshots(sa, sb)
    assert m["counters"]["c"]["k=x"] == 4            # counters add
    assert m["gauges"]["g"][""] == 3                 # gauges take rhs
    assert sum(m["histograms"]["h"][""]["counts"]) == 6
    assert m["histograms"]["h"][""]["sum"] == pytest.approx(0.42)
    # mismatched edge grids must refuse to merge
    other = Registry()
    other.histogram("h", lo=1e-2).observe(0.1)
    with pytest.raises(ValueError):
        merge_snapshots(sa, other.snapshot())


def test_prometheus_text_exposition():
    reg = Registry()
    reg.counter("req_total", "requests", labels=("route",)) \
        .labels(route="a").inc(2)
    reg.gauge("level").set(1)
    h = reg.histogram("lat", lo=0.1, hi=10.0, bins_per_decade=1)
    h.observe(0.5)
    h.observe(50.0)
    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text
    assert 'req_total{route="a"} 2' in text
    assert "level 1" in text
    assert 'lat_bucket{le="+Inf"} 2' in text
    assert "lat_count 2" in text
    # cumulative buckets are monotonically non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_bucket")]
    assert cums == sorted(cums)


def test_jsonl_exporter(tmp_path):
    reg = Registry()
    c = reg.counter("n")
    path = tmp_path / "m.jsonl"
    exp = JsonlExporter(str(path), reg, every=10, clock=lambda: 42.0)
    for tick in range(25):
        c.inc()
        exp.maybe_export(tick)
    exp.close(25)
    lines = [json.loads(s) for s in path.read_text().splitlines()]
    assert [r["tick"] for r in lines] == [0, 10, 20, 25]
    assert lines[-1]["metrics"]["counters"]["n"][""] == 25
    assert all(r["t"] == 42.0 for r in lines)
    exp.close()                          # idempotent
    assert len(path.read_text().splitlines()) == 4


def test_counterdict_is_a_dict_shim():
    reg = Registry()
    fam = reg.counter("disp_total", labels=("route",))
    d = CounterDict(fam, ("fused", "gather"))
    d["fused"] += 1
    d["fused"] += 1
    d["gather"] += 1
    assert d["fused"] == 2
    assert dict(d) == {"fused": 2, "gather": 1}
    assert d == {"fused": 2, "gather": 1}
    assert list(d) == ["fused", "gather"]
    assert "fused" in d and "bogus" not in d
    with pytest.raises(KeyError):
        d["bogus"]
    # the same values are visible through the registry
    assert reg.snapshot()["counters"]["disp_total"]["route=fused"] == 2


def test_statsview_read_write_and_derived_read_only():
    reg = Registry()
    view = StatsView()
    c = reg.counter("x_total")
    view.bind("x", lambda: int(c.value), c.set)
    view.bind("rate", lambda: 0.5)      # no setter: derived
    view["x"] += 3
    assert view["x"] == 3 and c.value == 3
    assert view["rate"] == 0.5
    assert dict(view) == {"x": 3, "rate": 0.5}
    assert view.get("missing") is None
    with pytest.raises(TypeError):
        view["rate"] = 1.0              # derived keys reject assignment
    with pytest.raises(KeyError):
        view["missing"] = 1


# ---------------------------------------------------------------------------
# Tracer + prof units.
# ---------------------------------------------------------------------------

def test_tracer_phase_chain_and_terminal():
    clk = FakeClock()
    tr = SpanTracer(clock=clk)
    tr.req_phase(7, "queued")
    clk.t = 1.0
    tr.req_phase(7, "prefill", slot=0)
    clk.t = 3.0
    tr.req_phase(7, "decode")
    clk.t = 5.0
    tr.req_terminal(7, "length", tokens=4)
    spans = tr.spans_for(7)
    assert [s.name for s in spans] == ["queued", "prefill", "decode"]
    # contiguous: each span closes exactly where the next opens
    for a, b in zip(spans, spans[1:]):
        assert a.t1 == b.t0
    assert all(s.t1 >= s.t0 for s in spans)
    terms = tr.terminals_for(7)
    assert len(terms) == 1
    assert terms[0].name == "terminal:length"
    assert terms[0].args["finish_reason"] == "length"

    ct = tr.chrome_trace()
    json.dumps(ct)                       # must be valid JSON
    xs = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == 3
    assert min(e["ts"] for e in ct["traceEvents"]
               if e["ph"] != "M") == 0.0  # ts is relative to first event


def test_global_tracer_hook():
    tr = SpanTracer(clock=lambda: 0.0)
    instant_global("allocator", "audit")     # no tracer: a no-op
    set_global_tracer(tr)
    try:
        instant_global("allocator", "audit", free=3)
    finally:
        set_global_tracer(None)
    instant_global("allocator", "audit")     # detached again
    assert len(tr.instants) == 1
    assert tr.instants[0].track == "allocator"
    assert tr.instants[0].args == {"free": 3}


def test_prof_disabled_is_shared_nullcontext():
    p = Prof(enabled=False)
    assert p.annotate("decode") is p.annotate("prefill")  # one shared obj
    with p.annotate("decode"):
        pass
    assert parse_tick_window("3:9") == (3, 9)
    for bad in ("9", "5:3", "-1:2", "a:b"):
        with pytest.raises(ValueError):
            parse_tick_window(bad)


# ---------------------------------------------------------------------------
# Engine integration.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke():
    cfg = registry.get_smoke_config("qwen3_1_7b")
    model = get_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n=4, seed=5, max_new=8, **kw):
    rs = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rs.randint(0, cfg.vocab_size,
                                      size=int(rs.randint(4, 12))).tolist(),
                    max_new_tokens=max_new, **kw)
            for i in range(n)]


def test_engine_off_is_structurally_noop(smoke):
    cfg, model, params = smoke
    eng = Engine(model, cfg, params, n_slots=2, max_len=32,
                 max_prompt_len=16)
    assert eng._tracer is None
    assert eng._obs_tick is None
    assert not eng._prof.enabled
    assert not eng.obs.enabled
    # the registry is still live: stats reads go through it
    eng.stats["tokens_out"] += 2
    snap = eng.obs.registry.snapshot()
    assert snap["counters"]["serve_tokens_out_total"][""] == 2
    assert "serve_acceptance_rate" in snap["gauges"]


def test_engine_streams_identical_with_obs_on(smoke):
    cfg, model, params = smoke
    runs = []
    for obs in (None, Observability(tracer=SpanTracer())):
        reqs = _reqs(cfg)
        eng = Engine(model, cfg, params, n_slots=2, max_len=32,
                     max_prompt_len=16, obs=obs)
        eng.run(reqs, max_ticks=400)
        runs.append([r.generated for r in reqs])
    assert runs[0] == runs[1]


def test_engine_acceptance_rate_is_derived(smoke):
    cfg, model, params = smoke
    eng = Engine(model, cfg, params, n_slots=2, max_len=32,
                 max_prompt_len=16)
    assert eng.stats["acceptance_rate"] == 0.0
    eng.stats["drafted"] += 8
    eng.stats["accepted"] += 2
    assert eng.stats["acceptance_rate"] == 0.25
    eng.stats["drafted"] += 8            # recomputes without a spec tick
    assert eng.stats["acceptance_rate"] == 0.125
    with pytest.raises(TypeError):
        eng.stats["acceptance_rate"] = 0.9


def _chaos_run(smoke):
    """One seeded chaos run over a virtual clock; returns
    (requests, tracer, registry snapshot)."""
    cfg, model, params = smoke
    clock = FakeClock()
    fault = FaultPlan(seed=3, p_alloc_fail=0.08, p_spurious_stall=0.04,
                      nan_ticks=(5, 11), p_slow=0.05, slow_extra_s=123.0)
    obs = Observability(tracer=SpanTracer())
    eng = Engine(model, cfg, params, n_slots=3, max_len=48,
                 max_prompt_len=24, paged=True, block_size=8, n_blocks=10,
                 clock=clock, fault=fault, obs=obs)
    reqs = _reqs(cfg, n=6, seed=9, max_new=10)
    reqs[3].deadline_s = 0.5             # will expire mid-run
    reqs[4].max_preemptions = 0          # first preemption is terminal
    for r in reqs:
        eng.submit(r)
    for _ in range(300):
        if not eng.has_work:
            break
        eng.tick()
        clock.t += 0.05
    assert all(r.done for r in reqs)
    obs.close()
    return reqs, obs.tracer, obs.registry.snapshot()


def test_chaos_trace_complete_and_deterministic(smoke):
    reqs, tr, snap = _chaos_run(smoke)

    for r in reqs:
        spans = tr.spans_for(r.rid)
        assert spans, f"rid={r.rid}: no spans"
        assert spans[0].name == "queued"
        # contiguous, time-ordered, non-negative durations
        for s in spans:
            assert s.t1 >= s.t0
        for a, b in zip(spans, spans[1:]):
            assert a.t1 == b.t0, f"rid={r.rid}: gap between phases"
        # exactly one terminal event, agreeing with the request
        terms = tr.terminals_for(r.rid)
        assert len(terms) == 1, f"rid={r.rid}: {len(terms)} terminals"
        assert terms[0].name == f"terminal:{r.finish_reason}"
        # the terminal closes the chain: nothing opens after it
        assert all(s.t1 <= terms[0].t for s in spans)
        # a preempted request's backoff span follows its preempt instant
        preempts = [i for i in tr.instants
                    if i.track == f"req {r.rid}" and i.name == "preempt"]
        if preempts:
            backoffs = [s for s in spans if s.name == "backoff"]
            assert backoffs, f"rid={r.rid}: preempt without backoff span"

    # the chaos knobs must actually have fired to make this test count
    names = {i.name for i in tr.instants}
    assert "fault:corrupt_logits" in names
    assert "fault:slow_tick" in names
    # Chrome export is valid JSON with every request track named
    ct = tr.chrome_trace()
    json.dumps(ct)
    tracks = {e["args"]["name"] for e in ct["traceEvents"]
              if e["ph"] == "M"}
    assert {f"req {r.rid}" for r in reqs} <= tracks

    # replay determinism: same seeds + virtual clock => identical trace
    # and identical metrics snapshot
    reqs2, tr2, snap2 = _chaos_run(smoke)
    assert [r.finish_reason for r in reqs] == \
        [r.finish_reason for r in reqs2]
    assert json.dumps(ct, sort_keys=True) == \
        json.dumps(tr2.chrome_trace(), sort_keys=True)
    assert json.dumps(snap, sort_keys=True) == \
        json.dumps(snap2, sort_keys=True)


def test_engineered_terminals_timeout_rejected_preempted_limit(smoke):
    cfg, model, params = smoke

    # timeout: a queued request's SLO expires while another holds the slot
    clock = FakeClock()
    obs = Observability(tracer=SpanTracer())
    eng = Engine(model, cfg, params, n_slots=1, max_len=32,
                 max_prompt_len=16, clock=clock, obs=obs)
    hog = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=12)
    slo = Request(rid=1, prompt=[4, 5, 6], max_new_tokens=4,
                  deadline_s=0.5)
    eng.submit(hog)
    eng.tick()                           # hog admitted
    eng.submit(slo)
    clock.t = 2.0                        # past rid=1's deadline
    eng.tick()
    assert slo.finish_reason == "timeout"
    assert [i.name for i in obs.tracer.terminals_for(1)] == \
        ["terminal:timeout"]
    # the queued span still closed (no dangling open phase)
    assert obs.tracer.spans_for(1)[-1].t1 == 2.0

    # rejected: the ladder's shed rung bounds the queue
    obs = Observability(tracer=SpanTracer())
    eng = Engine(model, cfg, params, n_slots=1, max_len=32,
                 max_prompt_len=16, queue_bound=1, obs=obs)
    eng._set_level(len(eng._levels) - 1)           # force "shed"
    victims = _reqs(cfg, n=3, seed=11, max_new=2)
    for r in victims:
        eng.submit(r)
    shed = [r for r in victims if r.finish_reason == "rejected"]
    assert shed, "shed level + bounded queue produced no rejection"
    for r in shed:
        assert [i.name for i in obs.tracer.terminals_for(r.rid)] == \
            ["terminal:rejected"]

    # preempted_limit: a dry pool deadlock preempts the only active
    # request, whose requeue budget is zero
    obs = Observability(tracer=SpanTracer())
    eng = Engine(model, cfg, params, n_slots=1, max_len=64,
                 max_prompt_len=8, paged=True, block_size=4, n_blocks=3,
                 obs=obs)
    doomed = Request(rid=0, prompt=[1] * 6, max_new_tokens=30,
                     max_preemptions=0)
    eng.run([doomed], max_ticks=100)
    assert doomed.finish_reason == "preempted_limit"
    assert [i.name for i in obs.tracer.terminals_for(0)] == \
        ["terminal:preempted_limit"]
