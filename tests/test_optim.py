"""Optimizer tests: AdamW/SGD mechanics, param groups, the paper's lr
multipliers, schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptimizerConfig, constant_schedule, cosine_schedule,
                         make_optimizer, step_decay_schedule)
from repro.optim.optimizers import tree_add


def _params():
    return {
        "layer": {"sell": {"a": jnp.ones((4,)), "d": jnp.ones((4,))},
                  "w": jnp.ones((4, 4))},
        "norm": {"scale": jnp.ones((4,))},
    }


def test_adamw_descends_quadratic():
    opt = make_optimizer(OptimizerConfig(lr=0.1, weight_decay=0.0),
                         constant_schedule(0.1))
    p = {"x": jnp.asarray([3.0, -2.0])}
    s = opt.init(p)
    for i in range(200):
        g = {"x": 2 * p["x"]}
        u, s = opt.update(g, s, p, jnp.asarray(i))
        p = tree_add(p, u)
    assert float(jnp.abs(p["x"]).max()) < 1e-2


def test_sgd_momentum_matches_caffe_formula():
    cfg = OptimizerConfig(kind="sgd", lr=0.1, momentum=0.9, weight_decay=0.0,
                          grad_clip=0.0)
    opt = make_optimizer(cfg, constant_schedule(0.1))
    p = {"x": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"x": jnp.asarray([1.0])}
    u1, s = opt.update(g, s, p, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(u1["x"]), [-0.1], atol=1e-6)
    u2, s = opt.update(g, s, p, jnp.asarray(1))
    # mom = 0.9*0.1 + 0.1 = 0.19
    np.testing.assert_allclose(np.asarray(u2["x"]), [-0.19], atol=1e-6)


def test_paper_lr_multiplier_groups():
    """x24 on A, x12 on D, x1 elsewhere (paper section 6.2)."""
    groups = ((r"sell/a$", {"lr_mult": 24.0, "weight_decay": 0.0}),
              (r"sell/d$", {"lr_mult": 12.0, "weight_decay": 0.0}))
    cfg = OptimizerConfig(kind="sgd", lr=1.0, momentum=0.0, weight_decay=0.0,
                          grad_clip=0.0, groups=groups)
    opt = make_optimizer(cfg, constant_schedule(1.0))
    p = _params()
    s = opt.init(p)
    g = jax.tree.map(jnp.ones_like, p)
    u, _ = opt.update(g, s, p, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(u["layer"]["sell"]["a"]),
                               -24.0 * np.ones(4), atol=1e-5)
    np.testing.assert_allclose(np.asarray(u["layer"]["sell"]["d"]),
                               -12.0 * np.ones(4), atol=1e-5)
    np.testing.assert_allclose(np.asarray(u["layer"]["w"]),
                               -1.0 * np.ones((4, 4)), atol=1e-5)


def test_weight_decay_exclusion():
    groups = ((r"sell/|norm", {"weight_decay": 0.0}),)
    cfg = OptimizerConfig(lr=0.0, weight_decay=0.5, grad_clip=0.0,
                          groups=groups)
    opt = make_optimizer(cfg, constant_schedule(0.0))
    # with lr=0 nothing moves regardless; instead verify via update values
    cfg = OptimizerConfig(lr=1.0, b1=0.0, b2=0.0, eps=1e-9,
                          weight_decay=0.5, grad_clip=0.0, groups=groups)
    opt = make_optimizer(cfg, constant_schedule(1.0))
    p = _params()
    s = opt.init(p)
    g = jax.tree.map(jnp.zeros_like, p)
    u, _ = opt.update(g, s, p, jnp.asarray(0))
    # zero grads: update = -lr * wd * p for decayed leaves, 0 for excluded
    assert float(jnp.abs(u["layer"]["sell"]["a"]).max()) < 1e-6
    assert float(jnp.abs(u["norm"]["scale"]).max()) < 1e-6
    np.testing.assert_allclose(np.asarray(u["layer"]["w"]),
                               -0.5 * np.ones((4, 4)), atol=1e-5)


def test_grad_clip_global_norm():
    cfg = OptimizerConfig(kind="sgd", lr=1.0, momentum=0.0,
                          weight_decay=0.0, grad_clip=1.0)
    opt = make_optimizer(cfg, constant_schedule(1.0))
    p = {"x": jnp.zeros((3,))}
    s = opt.init(p)
    g = {"x": jnp.asarray([30.0, 40.0, 0.0])}  # norm 50 -> scaled by 1/50
    u, _ = opt.update(g, s, p, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(u["x"]), [-0.6, -0.8, 0.0],
                               atol=1e-5)


def test_step_decay_schedule_paper():
    sch = step_decay_schedule(0.1, decay=0.1, every=100)
    assert abs(float(sch(jnp.asarray(0))) - 0.1) < 1e-6
    assert abs(float(sch(jnp.asarray(99))) - 0.1) < 1e-6
    assert abs(float(sch(jnp.asarray(100))) - 0.01) < 1e-6
    assert abs(float(sch(jnp.asarray(250))) - 0.001) < 1e-6


def test_cosine_schedule_monotone_warmup():
    sch = cosine_schedule(1.0, warmup=10, total=100)
    vals = [float(sch(jnp.asarray(i))) for i in range(15)]
    assert vals[0] < vals[5] < vals[9]
    assert abs(vals[10] - 1.0) < 0.05


def test_compact_state_bf16():
    cfg = OptimizerConfig(compact_state=True)
    opt = make_optimizer(cfg, constant_schedule(1e-3))
    s = opt.init({"x": jnp.zeros((4,), jnp.float32)})
    assert s["m"]["x"].dtype == jnp.bfloat16
    assert s["v"]["x"].dtype == jnp.bfloat16
