"""Fused paged-attention decode/verify kernel: parity and routing.

* direct kernel-vs-gather parity on synthetic operands: decode (T=1) and
  verify (T=k+1) grids, ragged positions straddling page boundaries,
  sliding window + logit softcap, page-table padding (max_blocks not a
  multiple of the page chunk), parked rows, in-contract unmapped tables
  (admission-tick shapes), and bfloat16 pools with BITWISE scatter parity;
* dispatch discipline: CPU default routes to the gather fallback, forcing
  the kernel routes fused, an over-budget block (no (page_chunk,
  head_block) fits VMEM) falls back to gather — every decision recorded in
  ``ops.PAGED_ATTN_DISPATCHES``;
* engine-level greedy stream identity, fused vs gather, for every pageable
  family in plain decode AND speculative verify;
* page-recycling regression: pages freed by eviction and LIFO-remapped to
  a *different* slot mid-stream must not leak stale K/V through the causal
  mask (dense parity across evict->admit cycles on a tight pool);
* analytic ``attn_kernel_bytes`` / ``attn_gather_bytes`` engine counters:
  kernel traffic strictly below gather's and independent of the per-slot
  page-table length for a fixed stream.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ops
from repro.kernels import paged_attn
from repro.models import get_model
from repro.serving import Engine, Request
from repro.spec import ModelDraft


# ---------------------------------------------------------------------------
# Direct kernel parity vs the block-table gather (no engine).
# ---------------------------------------------------------------------------

def _gather_ref(q, knew, vnew, k_pages, v_pages, tbl, pos, window, softcap):
    """The gather path's math, transcribed from models/attention.py:
    scatter the new tokens, materialise the (B, virtual, Hkv, Dh) view
    through the routed table, mask causally + by window, soft-capped SDPA."""
    b, t, hq, dh = q.shape
    hkv = knew.shape[2]
    n_pages, bs = k_pages.shape[0], k_pages.shape[1]
    mb = tbl.shape[1]
    virtual = mb * bs
    qpos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    blk = jnp.minimum(qpos // bs, mb - 1)
    phys = jnp.take_along_axis(tbl, blk, axis=1)
    writable = jnp.logical_and(phys >= 0, qpos < virtual)
    phys = jnp.where(writable, phys, n_pages - 1)
    off = qpos % bs
    k_pages = k_pages.at[phys, off].set(knew.astype(k_pages.dtype))
    v_pages = v_pages.at[phys, off].set(vnew.astype(v_pages.dtype))
    rt = jnp.where(tbl >= 0, tbl, 0)
    ck = k_pages[rt].reshape(b, virtual, hkv, dh)
    cv = v_pages[rt].reshape(b, virtual, hkv, dh)
    kpos = jnp.arange(virtual, dtype=jnp.int32)[None, :]
    causal = kpos[:, None, :] <= qpos[:, :, None]
    inw = jnp.where(window > 0,
                    qpos[:, :, None] - kpos[:, None, :] < window, True)
    mask = jnp.logical_and(causal, inw)
    group = hq // hkv
    qg = q.reshape(b, t, hkv, group, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck.astype(jnp.float32)) * dh**-0.5
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, cv.astype(jnp.float32))
    return o.reshape(b, t, hq, dh).astype(q.dtype), k_pages, v_pages


def _seq_tables(b, mb, nb):
    t = np.arange(b * mb, dtype=np.int32).reshape(b, mb)
    assert t.max() < nb
    return jnp.asarray(t)


def _unmapped_tables(b, mb, nb):
    # admission-tick shape: row 0 mapped only below its frontier, row 1
    # fully unmapped but PARKED (pos == virtual) — the only unmapped states
    # the allocator ever hands the kernel
    t = np.full((b, mb), -1, np.int32)
    t[0, :2] = [3, 4]
    return jnp.asarray(t)


_CASES = {
    "decode-global": dict(b=3, t=1, hkv=4, group=2, dh=8, bs=4, mb=6, nb=32,
                          window=0, softcap=0.0, pc=2, bh=2,
                          positions=[5, 0, 17], tables=_seq_tables),
    "verify-ragged-parked": dict(b=4, t=4, hkv=4, group=2, dh=16, bs=4,
                                 mb=6, nb=32, window=0, softcap=0.0, pc=2,
                                 bh=4, positions=[2, 7, 22, 24],
                                 tables=_seq_tables),
    "verify-window-pad": dict(b=2, t=3, hkv=4, group=1, dh=8, bs=4, mb=5,
                              nb=16, window=6, softcap=50.0, pc=2, bh=2,
                              positions=[9, 14], tables=_seq_tables),
    "decode-unmapped": dict(b=2, t=1, hkv=2, group=2, dh=8, bs=4, mb=4,
                            nb=16, window=0, softcap=0.0, pc=2, bh=2,
                            positions=[6, 16], tables=_unmapped_tables),
    "decode-bf16": dict(b=3, t=2, hkv=4, group=2, dh=8, bs=4, mb=6, nb=32,
                        window=0, softcap=0.0, pc=2, bh=2,
                        positions=[5, 0, 17], tables=_seq_tables,
                        dtype=jnp.bfloat16),
}


@pytest.mark.parametrize("case", sorted(_CASES))
def test_kernel_matches_gather(case):
    c = dict(_CASES[case])
    dtype = c.pop("dtype", jnp.float32)
    tables, positions, pc, bh = (c.pop("tables"), c.pop("positions"),
                                 c.pop("pc"), c.pop("bh"))
    b, t, hkv, group, dh = c["b"], c["t"], c["hkv"], c["group"], c["dh"]
    bs, mb, nb = c["bs"], c["mb"], c["nb"]
    r = jax.random.PRNGKey(0)
    q = jax.random.normal(r, (b, t, hkv * group, dh), dtype)
    knew = jax.random.normal(jax.random.fold_in(r, 1), (b, t, hkv, dh), dtype)
    vnew = jax.random.normal(jax.random.fold_in(r, 2), (b, t, hkv, dh), dtype)
    kp = jax.random.normal(jax.random.fold_in(r, 3), (nb + 1, bs, hkv, dh),
                           dtype)
    vp = jax.random.normal(jax.random.fold_in(r, 4), (nb + 1, bs, hkv, dh),
                           dtype)
    tbl = tables(b, mb, nb)
    pos = jnp.asarray(positions, jnp.int32)
    win = jnp.int32(c["window"])
    ro, rk, rv = _gather_ref(q, knew, vnew, kp, vp, tbl, pos, win,
                             c["softcap"])
    fo, fk, fv = jax.jit(lambda *a: paged_attn.paged_attention(
        *a, softcap=c["softcap"], page_chunk=pc, head_block=bh,
        interpret=True))(q, knew, vnew, kp, vp, tbl, pos, win)
    live = np.asarray(pos) < mb * bs
    tol = dict(atol=2e-5, rtol=2e-5) if dtype == jnp.float32 else \
        dict(atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(
        np.asarray(fo, np.float32)[live], np.asarray(ro, np.float32)[live],
        **tol)
    # pools must match BITWISE outside the trash page: the fused scatter is
    # the same write the gather path does, not an approximation of it
    assert np.array_equal(np.asarray(fk)[:-1], np.asarray(rk)[:-1])
    assert np.array_equal(np.asarray(fv)[:-1], np.asarray(rv)[:-1])


def test_vmem_budget_block_picker():
    blk = paged_attn.pick_block(hkv=8, dh=128, group=4, t=4, bs=16,
                                itemsize=2)
    assert blk is not None
    pc, bh = blk
    assert 8 % bh == 0
    assert paged_attn.paged_attn_vmem_bytes(
        bs=16, dh=128, group=4, t=4, pc=pc, bh=bh,
        itemsize=2) <= paged_attn.VMEM_BUDGET
    # an impossible shape has no in-budget block
    assert paged_attn.pick_block(hkv=8, dh=2 ** 16, group=4, t=4, bs=16,
                                 itemsize=4) is None
    # clamp keeps a legal block, repairs a head_block that no longer
    # divides hkv, and rejects like pick_block when nothing fits
    assert paged_attn.clamp_block((2, 8), hkv=4, dh=64, group=2, t=1,
                                  bs=16, itemsize=2)[1] <= 4
    assert paged_attn.clamp_block((2, 2), hkv=8, dh=2 ** 16, group=4, t=4,
                                  bs=16, itemsize=4) is None


# ---------------------------------------------------------------------------
# Dispatch routing (mirrors the cascade dispatch-counter tests).
# ---------------------------------------------------------------------------

def test_route_cpu_default_is_gather(monkeypatch):
    monkeypatch.setattr(paged_attn, "FORCE_FUSED", False)
    before = dict(ops.PAGED_ATTN_DISPATCHES)
    blk = ops.paged_attn_route(8, 64, 4, 1, 16, jnp.float32)
    if jax.default_backend() == "tpu":
        assert blk is not None
        assert ops.PAGED_ATTN_DISPATCHES["fused"] == before["fused"] + 1
    else:
        assert blk is None
        assert ops.PAGED_ATTN_DISPATCHES["gather"] == before["gather"] + 1


def test_route_forced_is_fused(monkeypatch):
    monkeypatch.setattr(paged_attn, "FORCE_FUSED", True)
    before = dict(ops.PAGED_ATTN_DISPATCHES)
    blk = ops.paged_attn_route(8, 64, 4, 1, 16, jnp.float32)
    assert blk is not None
    pc, bh = blk
    assert pc >= 1 and 8 % bh == 0
    assert ops.PAGED_ATTN_DISPATCHES["fused"] == before["fused"] + 1
    assert ops.PAGED_ATTN_DISPATCHES["gather"] == before["gather"]


def test_route_over_budget_falls_back(monkeypatch):
    monkeypatch.setattr(paged_attn, "FORCE_FUSED", True)
    monkeypatch.setattr(paged_attn, "clamp_block", lambda *a, **kw: None)
    before = dict(ops.PAGED_ATTN_DISPATCHES)
    assert ops.paged_attn_route(8, 64, 4, 1, 16, jnp.float32) is None
    assert ops.PAGED_ATTN_DISPATCHES["gather"] == before["gather"] + 1
    assert ops.PAGED_ATTN_DISPATCHES["fused"] == before["fused"]


# ---------------------------------------------------------------------------
# Engine-level stream identity, fused vs gather, all pageable families.
# ---------------------------------------------------------------------------

PAGED_ARCHS = ["qwen3_1_7b", "seamless_m4t_large_v2", "zamba2_1_2b"]

N_SLOTS, MAX_LEN, MAX_PROMPT, BLOCK = 2, 32, 12, 8


def _junk_draft_cfg(cfg):
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=1, n_encoder_layers=1)
    return dataclasses.replace(cfg, n_layers=max(1, cfg.n_layers - 1))


@pytest.fixture(scope="module", params=PAGED_ARCHS)
def served_arch(request):
    cfg = registry.get_smoke_config(request.param)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)

    fes = [jax.random.normal(
               jax.random.fold_in(jax.random.PRNGKey(7), i),
               (1, cfg.n_frontend_tokens or 16, cfg.d_model))
           if cfg.family == "encdec" else None
           for i in range(3 * N_SLOTS)]

    def make_requests():
        rs = np.random.RandomState(1)
        return [Request(rid=i,
                        prompt=rs.randint(0, cfg.vocab_size,
                                          size=4 + i).tolist(),
                        max_new_tokens=5 + i % 3, frontend_embeds=fes[i])
                for i in range(3 * N_SLOTS)]   # 3x slots -> slot reuse

    dense_reqs = make_requests()
    Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
           max_prompt_len=MAX_PROMPT).run(dense_reqs, max_ticks=600)
    assert all(r.done for r in dense_reqs)
    return cfg, model, params, make_requests, dense_reqs


def _run_paged(arch, make_requests, fused, monkeypatch, *, n_blocks=None,
               spec=False, max_ticks=600):
    cfg, model, params = arch
    monkeypatch.setattr(paged_attn, "FORCE_FUSED", fused)
    kw = {}
    if spec:
        kw = dict(spec_k=2, draft=ModelDraft(_junk_draft_cfg(cfg),
                                             rng=jax.random.PRNGKey(9)))
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, paged=True, block_size=BLOCK,
                 n_blocks=n_blocks, **kw)
    eng.run(reqs, max_ticks=max_ticks)
    return reqs, eng


def _assert_streams_equal(a, b, tag):
    for x, y in zip(a, b):
        assert y.generated == x.generated, (
            f"rid={x.rid} [{tag}]: {y.generated} != {x.generated}")
        assert y.finish_reason == x.finish_reason


def test_fused_decode_stream_identity(served_arch, monkeypatch):
    """Plain decode: fused and gather paged engines emit bit-identical
    greedy streams (and both match dense), with the dispatch counters
    recording that each run used the path it claims."""
    cfg, model, params, make_requests, dense_reqs = served_arch
    g_reqs, _ = _run_paged((cfg, model, params), make_requests, False,
                           monkeypatch)
    before = dict(ops.PAGED_ATTN_DISPATCHES)
    f_reqs, _ = _run_paged((cfg, model, params), make_requests, True,
                           monkeypatch)
    assert ops.PAGED_ATTN_DISPATCHES["fused"] > before["fused"]
    assert ops.PAGED_ATTN_DISPATCHES["gather"] == before["gather"]
    _assert_streams_equal(g_reqs, f_reqs, "decode fused-vs-gather")
    _assert_streams_equal(dense_reqs, f_reqs, "decode fused-vs-dense")


def test_fused_spec_verify_stream_identity(served_arch, monkeypatch):
    """Speculative verify (T = k+1 grid): same identity under a junk
    draft, so every rollback path crosses the fused kernel too."""
    cfg, model, params, make_requests, dense_reqs = served_arch
    g_reqs, _ = _run_paged((cfg, model, params), make_requests, False,
                           monkeypatch, spec=True)
    f_reqs, eng = _run_paged((cfg, model, params), make_requests, True,
                             monkeypatch, spec=True)
    _assert_streams_equal(g_reqs, f_reqs, "spec fused-vs-gather")
    _assert_streams_equal(dense_reqs, f_reqs, "spec fused-vs-dense")
    assert eng.stats["drafted"] > 0
    assert eng.allocator.in_use == 0


def test_page_recycling_no_stale_kv(served_arch, monkeypatch):
    """Pool of 5 pages for 6 requests needing ~12: every page is freed by
    an eviction and LIFO-remapped to a DIFFERENT slot mid-stream, so any
    stale K/V leaking past the causal/frontier mask in the fused kernel
    would corrupt the later streams.  Dense parity pins it down."""
    cfg, model, params, make_requests, dense_reqs = served_arch
    f_reqs, eng = _run_paged((cfg, model, params), make_requests, True,
                             monkeypatch, n_blocks=5, max_ticks=1200)
    _assert_streams_equal(dense_reqs, f_reqs, "recycled pages")
    assert eng.stats["preempted"] == 0
    assert eng.allocator.peak_in_use <= 5
    # reuse actually happened: the run needed more page-mappings than the
    # pool holds, so completion implies evict->admit recycling
    total_pages_needed = sum(-(-(r.prompt_len + len(r.generated)) // BLOCK)
                             for r in f_reqs)
    assert total_pages_needed > 5


def test_attn_byte_counters_stream_vs_gather(served_arch, monkeypatch):
    """The analytic per-tick counters: kernel bytes strictly below gather
    bytes, and independent of the page-table length (max_len) while
    gather's scale with it."""
    cfg, model, params, make_requests, _ = served_arch
    _, eng1 = _run_paged((cfg, model, params), make_requests, False,
                         monkeypatch)
    g1, k1 = eng1.stats["attn_gather_bytes"], eng1.stats["attn_kernel_bytes"]
    assert 0 < k1 < g1
    # double max_len => double the per-slot page table; same streams
    monkeypatch.setattr(paged_attn, "FORCE_FUSED", False)
    reqs = make_requests()
    eng2 = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=2 * MAX_LEN,
                  max_prompt_len=MAX_PROMPT, paged=True, block_size=BLOCK)
    eng2.run(reqs, max_ticks=600)
    g2, k2 = eng2.stats["attn_gather_bytes"], eng2.stats["attn_kernel_bytes"]
    assert k2 == k1        # streamed bytes depend on lengths, not max_len
    assert g2 == 2 * g1    # gathered bytes scale with the virtual row
