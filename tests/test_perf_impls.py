"""Equivalence tests for the performance-optimized implementations
(EXPERIMENTS.md section Perf): chunked attention == vanilla, one-hot CE ==
gather CE, scatter MoE == einsum MoE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model
from repro.models.common import ModelConfig, cross_entropy


def _cfg(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=101, dtype="float32")
    return ModelConfig(**{**base, **kw})


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (8, 0.0), (0, 30.0),
                                            (5, 20.0)])
def test_chunked_attention_equals_vanilla(window, softcap):
    from repro.models import attention as attn_mod
    cfg_v = _cfg(attn_impl="vanilla", sliding_window=window,
                 attn_logit_softcap=softcap)
    cfg_c = dataclasses.replace(cfg_v, attn_impl="chunked", attn_chunk=8)
    rng = jax.random.PRNGKey(0)
    params = attn_mod.init_attention(rng, cfg_v)
    b, s = 2, 37   # deliberately not a multiple of the chunk
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, 64))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    w = jnp.asarray(window, jnp.int32)
    yv = attn_mod.attention(params, x, pos, w, cfg_v)
    yc = attn_mod.attention(params, x, pos, w, cfg_c)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yv),
                               atol=1e-4, rtol=1e-4)


def test_chunked_attention_grads_match():
    from repro.models import attention as attn_mod
    cfg_v = _cfg(attn_impl="vanilla")
    cfg_c = dataclasses.replace(cfg_v, attn_impl="chunked", attn_chunk=8)
    rng = jax.random.PRNGKey(3)
    params = attn_mod.init_attention(rng, cfg_v)
    x = jax.random.normal(rng, (1, 16, 64))
    pos = jnp.arange(16, dtype=jnp.int32)[None]
    w = jnp.zeros((), jnp.int32)

    def loss(p, cfg):
        return jnp.sum(attn_mod.attention(p, x, pos, w, cfg) ** 2)

    gv = jax.grad(loss)(params, cfg_v)
    gc = jax.grad(loss)(params, cfg_c)
    for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=1e-3)


def test_onehot_ce_equals_gather_ce():
    rng = jax.random.PRNGKey(1)
    logits = jax.random.normal(rng, (4, 16, 101))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (4, 16), 0, 101)
    labels = labels.at[:, -1].set(-1)  # masked tail
    lg = cross_entropy(logits, labels, _cfg(ce_impl="gather"))
    lo = cross_entropy(logits, labels, _cfg(ce_impl="onehot"))
    np.testing.assert_allclose(float(lg), float(lo), atol=1e-5)


def test_onehot_ce_grads_match():
    rng = jax.random.PRNGKey(2)
    logits = jax.random.normal(rng, (2, 8, 33))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (2, 8), 0, 33)
    gg = jax.grad(lambda l: cross_entropy(l, labels, _cfg(ce_impl="gather")))(logits)
    go = jax.grad(lambda l: cross_entropy(l, labels, _cfg(ce_impl="onehot")))(logits)
    np.testing.assert_allclose(np.asarray(gg), np.asarray(go),
                               atol=1e-5, rtol=1e-4)


def test_scatter_moe_equals_einsum_moe():
    from repro.models import mlp as mlp_mod
    cfg_e = _cfg(n_experts=8, n_shared_experts=1, top_k=2,
                 capacity_factor=8.0)  # big capacity: no drops -> exact
    cfg_s = dataclasses.replace(cfg_e, moe_impl="scatter")
    rng = jax.random.PRNGKey(5)
    params = mlp_mod.init_moe(rng, cfg_e)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, 64))
    ye = mlp_mod.moe(params, x, cfg_e)
    ys = mlp_mod.moe(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ye),
                               atol=1e-4, rtol=1e-4)


def test_scatter_moe_with_drops_matches_einsum():
    """Tight capacity: both impls drop the SAME slots."""
    from repro.models import mlp as mlp_mod
    cfg_e = _cfg(n_experts=4, top_k=2, capacity_factor=0.5)
    cfg_s = dataclasses.replace(cfg_e, moe_impl="scatter")
    rng = jax.random.PRNGKey(6)
    params = mlp_mod.init_moe(rng, cfg_e)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 64))
    ye = mlp_mod.moe(params, x, cfg_e)
    ys = mlp_mod.moe(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ye),
                               atol=1e-4, rtol=1e-4)


def test_scatter_moe_grads_match():
    from repro.models import mlp as mlp_mod
    cfg_e = _cfg(n_experts=4, top_k=2, capacity_factor=4.0)
    cfg_s = dataclasses.replace(cfg_e, moe_impl="scatter")
    rng = jax.random.PRNGKey(7)
    params = mlp_mod.init_moe(rng, cfg_e)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 8, 64))

    def loss(p, cfg):
        return jnp.sum(mlp_mod.moe(p, x, cfg) ** 2)

    ge = jax.grad(loss)(params, cfg_e)
    gs = jax.grad(loss)(params, cfg_s)
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3)


def test_full_model_with_all_optimizations():
    """A model with every perf knob on trains one step, finite loss."""
    cfg = dataclasses.replace(
        registry.get_smoke_config("deepseek_moe_16b"),
        attn_impl="chunked", attn_chunk=8, ce_impl="onehot",
        moe_impl="scatter")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    loss = m.loss_fn(p, {"tokens": toks, "labels": toks}, cfg)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda pp: m.loss_fn(pp, {"tokens": toks, "labels": toks},
                                      cfg))(p)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
