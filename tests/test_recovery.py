"""Paper section 6.1 (fast version): ACDC cascades approximate a dense
linear operator by SGD, and the identity+noise init matters.

The full Figure-3 sweep lives in examples/linear_recovery.py and
benchmarks/bench_fig3_recovery.py; here we assert the two qualitative
claims on a reduced budget so CI stays fast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import acdc as A


def _problem(n=16, m=2000, seed=0):
    r = np.random.RandomState(seed)
    x = r.rand(m, n).astype(np.float32)
    w = r.rand(n, n).astype(np.float32)
    y = x @ w + 1e-2 * r.randn(m, n).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(w)


def _train(cfg, x, y, steps=400, lr=3e-2, seed=0):
    """Adam via the scan-compiled Fig-3 trainer (fast + depth-stable)."""
    from benchmarks import bench_fig3_recovery as fig3
    loss, _ = fig3.train(cfg, x, y, steps=steps, lr0=lr, seed=seed)
    return loss


def test_deeper_cascade_approximates_better():
    """Figure 3 left, reduced budget: loss improves monotonically-ish in K.

    (Reaching the noise floor needs the full benchmark budget — see
    benchmarks/bench_fig3_recovery.py; CI asserts the ordering claim.)
    """
    x, y, w = _problem()
    l1 = _train(A.ACDCConfig(n=16, k=1, bias=False), x, y)
    l8 = _train(A.ACDCConfig(n=16, k=8, bias=False), x, y,
                steps=600, lr=1e-2)
    assert l8 < 0.8 * l1, (l1, l8)


def test_identity_init_beats_standard_init_when_deep():
    """Figure 3 right: N(1, 0.1) trains at depth; N(0, sigma) collapses."""
    x, y, w = _problem()
    good = _train(A.ACDCConfig(n=16, k=8, bias=False,
                               init_mean=1.0, init_std=0.1), x, y,
                  steps=600, lr=1e-2)
    bad = _train(A.ACDCConfig(n=16, k=8, bias=False,
                              init_mean=0.0, init_std=1e-3), x, y,
                 steps=600, lr=1e-2)
    assert good < bad / 2, (good, bad)


def test_k1_exactly_representable_operator_is_recovered():
    """If W_true IS an ACDC operator, K=1 recovery reaches ~zero loss."""
    n = 16
    cfg = A.ACDCConfig(n=n, k=1, bias=False)
    p_true = A.init_acdc_params(jax.random.PRNGKey(7), cfg)
    r = np.random.RandomState(0)
    x = jnp.asarray(r.rand(2000, n).astype(np.float32))
    y = A.acdc_cascade(p_true, x, cfg)
    l = _train(cfg, x, y, steps=600, lr=5e-2, seed=1)
    assert l < 1e-3, l
