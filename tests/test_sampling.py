"""Unit tests for the top-k / top-p filtering math in repro.serving.sampler
and its integration into make_serve_step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import apply_top_k, apply_top_p, sample

NEG = -1e29     # anything below this counts as "masked"


def _kept(filtered):
    return set(np.flatnonzero(np.asarray(filtered) > NEG).tolist())


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------

def test_top_k_keeps_k_largest():
    logits = jnp.array([0.1, 3.0, -1.0, 2.0, 0.5])
    assert _kept(apply_top_k(logits, 2)) == {1, 3}
    assert _kept(apply_top_k(logits, 1)) == {1}
    # kept values are untouched
    out = np.asarray(apply_top_k(logits, 2))
    np.testing.assert_allclose(out[[1, 3]], [3.0, 2.0])


def test_top_k_disabled_and_full():
    logits = jnp.array([0.1, 3.0, -1.0])
    np.testing.assert_array_equal(np.asarray(apply_top_k(logits, 0)),
                                  np.asarray(logits))
    np.testing.assert_array_equal(np.asarray(apply_top_k(logits, 3)),
                                  np.asarray(logits))
    np.testing.assert_array_equal(np.asarray(apply_top_k(logits, 99)),
                                  np.asarray(logits))


def test_top_k_ties_at_threshold_kept():
    logits = jnp.array([2.0, 2.0, 1.0, 0.0])
    # k=1 with a tie at the max: both tied tokens survive (documented)
    assert _kept(apply_top_k(logits, 1)) == {0, 1}


def test_top_k_batched():
    logits = jnp.array([[0.0, 1.0, 2.0], [5.0, -1.0, 0.0]])
    out = np.asarray(apply_top_k(logits, 1))
    assert _kept(out[0]) == {2}
    assert _kept(out[1]) == {0}


# ---------------------------------------------------------------------------
# top-p
# ---------------------------------------------------------------------------

def test_top_p_nucleus_boundary():
    # probs = [0.5, 0.3, 0.15, 0.05] (descending by construction)
    probs = np.array([0.5, 0.3, 0.15, 0.05])
    logits = jnp.asarray(np.log(probs))
    # mass before token0 = 0, before token1 = 0.5, before token2 = 0.8:
    # p=0.7 keeps {0,1}; p=0.85 keeps {0,1,2}; p=0.4 keeps {0}
    assert _kept(apply_top_p(logits, 0.7)) == {0, 1}
    assert _kept(apply_top_p(logits, 0.85)) == {0, 1, 2}
    assert _kept(apply_top_p(logits, 0.4)) == {0}


def test_top_p_always_keeps_argmax():
    logits = jnp.array([10.0, 0.0, -5.0])
    assert _kept(apply_top_p(logits, 1e-6)) == {0}


def test_top_p_disabled():
    logits = jnp.array([0.3, 0.2, 0.1])
    for p in (0.0, 1.0, -1.0, 2.0):
        np.testing.assert_array_equal(np.asarray(apply_top_p(logits, p)),
                                      np.asarray(logits))


def test_top_p_unsorted_input_order_irrelevant():
    probs = np.array([0.15, 0.5, 0.05, 0.3])       # shuffled
    logits = jnp.asarray(np.log(probs))
    assert _kept(apply_top_p(logits, 0.7)) == {1, 3}


def test_top_p_batched_rows_independent():
    logits = jnp.asarray(np.log(np.array([
        [0.97, 0.01, 0.01, 0.01],
        [0.40, 0.30, 0.20, 0.10],
    ])))
    out = apply_top_p(logits, 0.6)
    assert _kept(out[0]) == {0}
    # row 1: mass before token1 = 0.4 < 0.6, before token2 = 0.7 >= 0.6
    assert _kept(out[1]) == {0, 1}


# ---------------------------------------------------------------------------
# sample() composition
# ---------------------------------------------------------------------------

def test_sample_greedy_is_argmax():
    logits = jnp.array([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
    out = sample(jax.random.PRNGKey(0), logits, method="greedy")
    np.testing.assert_array_equal(np.asarray(out), [1, 0])
    assert out.dtype == jnp.int32


def test_sample_temp_top_k1_equals_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    got = sample(jax.random.PRNGKey(2), logits, method="temp",
                 temperature=5.0, top_k=1)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_temp_respects_nucleus():
    probs = np.array([0.6, 0.3, 0.06, 0.04])
    logits = jnp.broadcast_to(jnp.asarray(np.log(probs)), (64, 4))
    got = np.asarray(sample(jax.random.PRNGKey(3), logits, method="temp",
                            top_p=0.7))
    assert set(got.tolist()) <= {0, 1}


def test_sample_rejects_unknown_method():
    with pytest.raises(ValueError):
        sample(jax.random.PRNGKey(0), jnp.zeros((4,)), method="beam")


def test_serve_step_top_k_matches_greedy():
    """make_serve_step with temp+top_k=1 must follow the greedy stream —
    the integration point of the sampler into the fused decode step."""
    from repro.configs import registry
    from repro.dist import steps as steps_mod
    from repro.models import get_model

    cfg = registry.get_smoke_config("qwen3_1_7b")
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    b, s = 2, 8
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (b, s), 0,
                              cfg.vocab_size)
    greedy = jax.jit(steps_mod.make_serve_step(model, cfg, sample="greedy"))
    topk1 = jax.jit(steps_mod.make_serve_step(model, cfg, sample="temp",
                                              temperature=3.0, top_k=1))
    cg = model.init_cache(cfg, b, s + 1)
    ck = model.init_cache(cfg, b, s + 1)
    for i in range(s):
        pos = jnp.full((b,), i, jnp.int32)
        tg, cg = greedy(params, cg, toks[:, i], pos, rng)
        tk, ck = topk1(params, ck, toks[:, i], pos,
                       jax.random.fold_in(rng, i))
        np.testing.assert_array_equal(np.asarray(tg), np.asarray(tk))
