"""SELL zoo tests: every baseline the paper compares against."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import sell as S

KINDS = ["dense", "low_rank", "circulant", "fastfood", "acdc", "afdf"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n_in,n_out", [(16, 16), (24, 40), (64, 32)])
def test_shapes_and_finite(kind, n_in, n_out):
    cfg = S.SellConfig(kind=kind, n_in=n_in, n_out=n_out, k=2, rank=4)
    p = S.init_sell_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, n_in))
    y = S.structured_linear(p, x, cfg)
    assert y.shape == (5, n_out)
    mag = jnp.abs(y) if kind == "afdf" else y
    assert bool(jnp.isfinite(mag).all())


@pytest.mark.parametrize("kind", ["low_rank", "circulant", "fastfood", "acdc"])
def test_linearity(kind):
    cfg = S.SellConfig(kind=kind, n_in=32, n_out=32, k=2, rank=4, bias=False)
    p = S.init_sell_params(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 32))
    w = S.sell_dense_equivalent(p, cfg)
    got = S.structured_linear(p, x, cfg)
    np.testing.assert_allclose(np.asarray(x @ w), np.asarray(got), atol=1e-4)


def test_circulant_structure():
    """The learned operator is exactly diag(a) @ circulant(c)."""
    n = 16
    cfg = S.SellConfig(kind="circulant", n_in=n, n_out=n, bias=False)
    p = S.init_sell_params(jax.random.PRNGKey(5), cfg)
    c = np.asarray(p["c"])
    R = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(n):
            R[i, j] = c[(j - i) % n]
    w = np.asarray(S.sell_dense_equivalent(p, cfg))
    np.testing.assert_allclose(w, np.diag(np.asarray(p["a"])) @ R, atol=1e-5)


def test_param_counts_scale_linearly():
    """SELL kinds are O(N); dense is O(N^2) (the paper's core claim)."""
    for n in [64, 128, 256]:
        dense = S.SellConfig(kind="dense", n_in=n, n_out=n).param_count()
        acdc = S.SellConfig(kind="acdc", n_in=n, n_out=n, k=2).param_count()
        ff = S.SellConfig(kind="fastfood", n_in=n, n_out=n).param_count()
        circ = S.SellConfig(kind="circulant", n_in=n, n_out=n).param_count()
        assert dense == n * n + n
        assert acdc == 2 * 3 * n            # k=2 x (a, d, bias)
        assert ff == 3 * n + n
        assert circ == 2 * n + n
        assert acdc < dense / 8


def test_param_count_matches_actual_tree():
    for kind in KINDS:
        cfg = S.SellConfig(kind=kind, n_in=48, n_out=48, k=3, rank=8)
        p = S.init_sell_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
        assert actual == cfg.param_count(), (kind, actual, cfg.param_count())


def test_afdf_theory_object_is_complex_composition():
    """AFDF_K == K-fold x -> ifft(fft(x*a)*d) (section 3 object)."""
    n = 8
    cfg = S.SellConfig(kind="afdf", n_in=n, n_out=n, k=2, bias=False)
    p = S.init_sell_params(jax.random.PRNGKey(1), cfg)
    x = np.random.RandomState(0).randn(2, n).astype(np.float32)
    h = x.astype(np.complex64)
    for i in range(2):
        a = np.asarray(p["a_re"][i]) + 1j * np.asarray(p["a_im"][i])
        d = np.asarray(p["d_re"][i]) + 1j * np.asarray(p["d_im"][i])
        h = np.fft.ifft(np.fft.fft(h * a, axis=-1) * d, axis=-1)
    got = np.asarray(S.structured_linear(p, jnp.asarray(x), cfg))
    np.testing.assert_allclose(got, h, atol=1e-4)


@given(st.sampled_from(["acdc", "circulant", "fastfood"]),
       st.integers(4, 64), st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_gradients_finite_property(kind, n, seed):
    cfg = S.SellConfig(kind=kind, n_in=n, n_out=n, k=2)
    p = S.init_sell_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, n))

    def loss(p):
        return jnp.sum(S.structured_linear(p, x, cfg) ** 2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())
