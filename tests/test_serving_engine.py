"""Continuous-batching engine: scheduler policy, stop conditions, and the
core isolation invariant — a request's output stream in a shared batch is
identical to running it alone."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model
from repro.serving import Engine, Request, RequestStatus, Scheduler


# ---------------------------------------------------------------------------
# Scheduler (host-side policy, no jax).
# ---------------------------------------------------------------------------

def _req(rid, plen=4):
    return Request(rid=rid, prompt=list(range(1, plen + 1)))


def test_scheduler_fifo_admission_and_release():
    sch = Scheduler(2)
    for i in range(4):
        sch.submit(_req(i))
    admitted = sch.admit()
    assert [(s, r.rid) for s, r in admitted] == [(0, 0), (1, 1)]
    assert sch.admit() == []            # batch full
    assert sch.has_work
    sch.release(0)
    admitted = sch.admit()
    assert [(s, r.rid) for s, r in admitted] == [(0, 2)]  # FIFO into slot 0
    sch.release(0)
    sch.release(1)
    assert [r.rid for _, r in sch.admit()] == [3]
    sch.release(0)
    assert not sch.has_work


def test_scheduler_rejects_double_submit_and_release():
    sch = Scheduler(1)
    r = _req(0)
    sch.submit(r)
    sch.admit()
    with pytest.raises(ValueError):
        sch.submit(r)                   # already active
    sch.release(0)
    with pytest.raises(ValueError):
        sch.release(0)                  # already free


# ---------------------------------------------------------------------------
# Engine (qwen smoke config; greedy so streams are deterministic).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = registry.get_smoke_config("qwen3_1_7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def test_engine_ragged_stream_matches_solo(qwen):
    """>= 3x batch-size ragged requests through 3 slots; every request's
    stream must equal a single-slot run of the same prompt (the acceptance
    criterion: slots are perfectly isolated)."""
    cfg, model, params = qwen
    rs = np.random.RandomState(0)
    n_slots = 3
    reqs = [
        Request(rid=i,
                prompt=rs.randint(0, cfg.vocab_size,
                                  size=rs.randint(3, 16)).tolist(),
                max_new_tokens=6)
        for i in range(3 * n_slots)
    ]
    eng = Engine(model, cfg, params, n_slots=n_slots, max_len=40,
                 max_prompt_len=16)
    eng.run(reqs, max_ticks=400)
    assert all(r.done for r in reqs)
    assert eng.stats["prefill_dispatches"] == len(reqs)
    # one solo engine, reused: same compiled programs for every reference
    solo = Engine(model, cfg, params, n_slots=1, max_len=40,
                  max_prompt_len=16)
    for r in reqs:
        ref = Request(rid=r.rid, prompt=list(r.prompt), max_new_tokens=6)
        solo.run([ref], max_ticks=200)
        assert ref.generated == r.generated, (
            f"rid={r.rid}: batched {r.generated} != solo {ref.generated}")


def test_engine_eos_stop_and_slot_reuse(qwen):
    cfg, model, params = qwen
    prompt = [5, 9, 2, 7]
    probe = Request(rid=0, prompt=list(prompt), max_new_tokens=8)
    eng = Engine(model, cfg, params, n_slots=2, max_len=32,
                 max_prompt_len=8)
    eng.run([probe], max_ticks=100)
    assert probe.finish_reason == "length"
    assert len(probe.generated) == 8

    # greedy is deterministic: making token i (its first occurrence in the
    # stream, i >= 1 so the stop happens on a DECODE tick, not at
    # admission) the EOS id must stop the same request after exactly i+1
    # tokens, and the freed slot must be reused by a queued request
    stop_at = next((i for i in range(1, len(probe.generated))
                    if probe.generated[i] not in probe.generated[:i]), None)
    if stop_at is None:
        pytest.skip("degenerate smoke stream: only one distinct token")
    eos = probe.generated[stop_at]
    r1 = Request(rid=1, prompt=list(prompt), max_new_tokens=8, eos_id=eos)
    r2 = Request(rid=2, prompt=list(prompt), max_new_tokens=2)
    r3 = Request(rid=3, prompt=list(prompt), max_new_tokens=2)
    eng2 = Engine(model, cfg, params, n_slots=2, max_len=32,
                  max_prompt_len=8)
    eng2.run([r1, r2, r3], max_ticks=100)
    assert r1.finish_reason == "eos"
    assert len(r1.generated) == stop_at + 1
    assert r1.generated == probe.generated[: stop_at + 1]
    assert r2.finish_reason == "length" and r3.finish_reason == "length"


def test_engine_cache_ceiling(qwen):
    """A request whose prompt + budget exceeds max_len stops at the cache
    ceiling instead of scribbling out of bounds."""
    cfg, model, params = qwen
    r = Request(rid=0, prompt=list(range(1, 9)), max_new_tokens=64)
    eng = Engine(model, cfg, params, n_slots=1, max_len=12,
                 max_prompt_len=8)
    eng.run([r], max_ticks=100)
    assert r.finish_reason == "cache_full"
    # tokens at positions 8..11 fit; the last sampled token is the one that
    # could no longer be written
    assert len(r.generated) == 12 - 8 + 1
    assert r.status is RequestStatus.FINISHED


def test_engine_rejects_oversized_prompt(qwen):
    cfg, model, params = qwen
    eng = Engine(model, cfg, params, n_slots=1, max_len=16,
                 max_prompt_len=4)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=[1] * 5))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=1, prompt=[]))


def test_engine_run_max_ticks_is_exact(qwen):
    """run(max_ticks=N) must raise after exactly N ticks, not N+1 (the old
    ``ticks > max_ticks`` check let one extra tick slip through)."""
    cfg, model, params = qwen
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=64)
    eng = Engine(model, cfg, params, n_slots=1, max_len=128,
                 max_prompt_len=4)
    with pytest.raises(RuntimeError, match="after 5 ticks"):
        eng.run([r], max_ticks=5)
    assert eng.stats["decode_ticks"] == 5


def test_engine_run_max_ticks_not_raised_when_drained(qwen):
    """A request that drains in exactly max_ticks ticks must not raise."""
    cfg, model, params = qwen
    # admission emits token 1, then 3 decode ticks emit tokens 2..4
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    eng = Engine(model, cfg, params, n_slots=1, max_len=32,
                 max_prompt_len=4)
    eng.run([r], max_ticks=3)
    assert r.done and len(r.generated) == 4


def test_engine_rng_streams_disjoint(qwen):
    """Decode-tick keys and admission keys must never collide — the old
    packing (``1 << 20 | tick`` vs raw ``rid`` folded into one base key)
    reused tick 0's key at tick 2**20 and collided rids >= 2**20 with
    decode ticks.  Boundary values across both streams must be unique."""
    cfg, model, params = qwen
    eng = Engine(model, cfg, params, n_slots=1, max_len=16,
                 max_prompt_len=4)
    cases = [eng._decode_rng(t) for t in
             (0, 1, 5, (1 << 20) - 1, 1 << 20, (1 << 20) + 1, 1 << 21)]
    cases += [eng._admit_rng(r) for r in
              (0, 1, 5, (1 << 20) - 1, 1 << 20, (1 << 20) | 5, 1 << 21)]
    keys = {tuple(np.asarray(jax.random.key_data(k)).ravel().tolist())
            for k in cases}
    assert len(keys) == len(cases), "RNG stream collision"


def test_engine_ttft_marks(qwen):
    cfg, model, params = qwen
    r = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3)
    eng = Engine(model, cfg, params, n_slots=1, max_len=16,
                 max_prompt_len=4)
    eng.run([r], max_ticks=50)
    assert r.t_submit is not None
    assert r.t_first_token is not None and r.t_first_token >= r.t_submit
    assert r.t_finish is not None and r.t_finish >= r.t_first_token
