"""Fault injection: FaultPlan determinism, allocator audit invariants, and
the seeded chaos replay — the CI gate that proves the overload machinery
*recovers*: every request reaches a terminal state, requests that finish
normally stream bit-identically to a fault-free run (recompute heals
preemptions and corrupt ticks), evicted requests keep clean stream
prefixes, and the page pool comes back leak-free."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model
from repro.serving import (
    BlockAllocator,
    Engine,
    FaultPlan,
    FinishReason,
    Request,
)


# ---------------------------------------------------------------------------
# FaultPlan (host-side, no jax).
# ---------------------------------------------------------------------------

def test_fault_plan_replays_exactly():
    """Two plans built with the same parameters see the same faults at the
    same decision points, even when the surfaces interleave differently —
    each surface draws from its own stream."""
    a = FaultPlan(seed=9, p_alloc_fail=0.3, p_spurious_stall=0.2,
                  p_nan=0.1, p_slow=0.2, slow_extra_s=1.5)
    b = FaultPlan(seed=9, p_alloc_fail=0.3, p_spurious_stall=0.2,
                  p_nan=0.1, p_slow=0.2, slow_extra_s=1.5)
    seq_a = [a.alloc_fail() for _ in range(50)]
    # b interleaves other surfaces between its alloc draws: the alloc
    # sequence must be unaffected
    seq_b = []
    for i in range(50):
        b.spurious_stall(i % 4)
        seq_b.append(b.alloc_fail())
        b.logits_corrupt(i)
        b.extra_tick_s(i)
    assert seq_a == seq_b
    assert a.injected["alloc_fail"] == b.injected["alloc_fail"]


def test_fault_plan_default_is_noop():
    p = FaultPlan()
    assert not p.alloc_fail()
    assert not p.spurious_stall(0)
    assert not p.logits_corrupt(0)
    assert p.extra_tick_s(0) == 0.0
    assert all(v == 0 for v in p.injected.values())


def test_fault_plan_explicit_ticks_fire_unconditionally():
    p = FaultPlan(nan_ticks=(3,), slow_ticks=(5,), slow_extra_s=2.0)
    assert not p.logits_corrupt(2)
    assert p.logits_corrupt(3)
    assert p.extra_tick_s(5) == 2.0
    assert p.extra_tick_s(6) == 0.0
    assert p.injected == {"alloc_fail": 0, "spurious_stall": 0,
                          "nan": 1, "slow": 1}


# ---------------------------------------------------------------------------
# Allocator audit: every release path must leave the pool consistent.
# ---------------------------------------------------------------------------

def test_audit_clean_across_all_release_paths():
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2,
                       max_blocks_per_slot=4)
    assert a.audit() == {"free": 6, "held": 0, "mapped": 0}
    a.alloc_slot(0, 7)                          # admission
    a.audit()
    assert a.ensure_range(0, 8, 3)              # decode/verify growth
    a.audit()
    a.trim_slot(0, 9)                           # speculative rollback
    a.audit()
    a.alloc_slot(1, 3)
    # dry-pool rollback: ensure_range must return ITS OWN pages on failure
    assert a.ensure_range(1, 4, 12) is False
    a.audit()
    a.free_slot(0)                              # eviction / preemption
    a.audit()
    a.free_slot(1)
    assert a.audit() == {"free": 6, "held": 0, "mapped": 0}


def test_audit_catches_corruption():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=2,
                       max_blocks_per_slot=2)
    a.alloc_slot(0, 3)
    blk = int(a.table[0, 0])
    a.table[1, 0] = blk                         # double-map
    with pytest.raises(AssertionError, match="double-mapped"):
        a.audit()
    a.table[1, 0] = -1
    a.table[0, 1] = a.trash                     # trash page mapped
    with pytest.raises(AssertionError, match="non-pool"):
        a.audit()
    a.table[0, 1] = -1
    a._free.append(blk)                         # page both free and held
    with pytest.raises(AssertionError, match="free and held"):
        a.audit()


def test_allocator_fault_denies_without_breaking_invariants():
    plan = FaultPlan(p_alloc_fail=1.0)
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2,
                       max_blocks_per_slot=4, fault=plan)
    assert not a.can_admit(3)                   # pages free, fault denies
    assert not a.ensure_range(0, 0, 1)
    assert a.n_free == 6
    a.audit()
    assert plan.injected["alloc_fail"] == 2


# ---------------------------------------------------------------------------
# Seeded chaos replay (the acceptance criterion).
# ---------------------------------------------------------------------------

ARCHS = ["qwen3_1_7b", "zamba2_1_2b"]


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in ARCHS:
        cfg = registry.get_smoke_config(name)
        model = get_model(cfg)
        out[name] = (cfg, model, model.init(jax.random.PRNGKey(0), cfg))
    return out


def _mk_requests(cfg, n=9, seed=11):
    rs = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rs.randint(0, cfg.vocab_size,
                                      size=int(rs.randint(4, 17))).tolist(),
                    max_new_tokens=int(rs.randint(8, 13)))
            for i in range(n)]


@pytest.mark.parametrize("name", ARCHS)
def test_chaos_run_recovers_clean(zoo, name):
    """Replay a seeded fault schedule — denied pages, spurious stalls,
    two corrupt-logit ticks, simulated stragglers — against a tight pool
    and assert the recovery invariants."""
    cfg, model, params = zoo[name]

    def build(fault=None):
        return Engine(model, cfg, params, n_slots=3, max_len=48,
                      max_prompt_len=24, paged=True, block_size=8,
                      n_blocks=10, fault=fault)

    base = _mk_requests(cfg)
    build().run(base, max_ticks=2000)
    assert all(r.finish_reason == "length" for r in base)

    reqs = _mk_requests(cfg)
    fault = FaultPlan(seed=3, p_alloc_fail=0.08, p_spurious_stall=0.04,
                      nan_ticks=(5, 11), p_slow=0.05, slow_extra_s=123.0)
    eng = build(fault)
    eng.run(reqs, max_ticks=4000)

    # every request reaches a terminal state with a known reason
    assert all(r.done for r in reqs)
    assert all(r.finish_reason in FinishReason.ALL for r in reqs)
    # the chaos actually bit: corrupt ticks healed via requeue
    assert eng.stats["corrupt_ticks"] >= 1
    assert eng.stats["requeued"] >= 1
    # recompute guarantee: normal finishes stream bit-identically,
    # terminal evictions keep a clean prefix
    for b, r in zip(base, reqs):
        if r.finish_reason in ("eos", "length"):
            assert r.generated == b.generated, (
                f"rid={r.rid}: chaos {r.generated} != base {b.generated}")
        else:
            assert b.generated[:len(r.generated)] == r.generated
    # leak-free pool
    eng.allocator.audit()
    assert eng.allocator.n_free == eng.allocator.n_blocks


def test_wall_clock_limit_exits_livelock(zoo):
    """A plan that denies every page forever livelocks the tick loop
    (nothing admits, the queue never drains); ``wall_clock_limit_s`` must
    exit with partial results instead of spinning until max_ticks."""
    cfg, model, params = zoo["qwen3_1_7b"]
    eng = Engine(model, cfg, params, n_slots=2, max_len=48,
                 max_prompt_len=16, paged=True, block_size=8,
                 fault=FaultPlan(p_alloc_fail=1.0))
    reqs = _mk_requests(cfg, n=3)
    out = eng.run(reqs, wall_clock_limit_s=1.5)
    assert eng.wall_clock_exceeded
    assert all(not r.done for r in out)         # partial state, not killed
    assert eng.stats["tokens_out"] == 0
    eng.allocator.audit()
