"""Paged block KV cache: allocator invariants and the acceptance
criterion — a greedy request stream served through the paged cache yields
token streams identical to the dense cache, for every family that pages,
including under slot reuse, ragged lengths, and a pool tight enough to
stall decode."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model
from repro.serving import BlockAllocator, Engine, Request, RequestStatus


# ---------------------------------------------------------------------------
# Allocator (host-side, no jax).
# ---------------------------------------------------------------------------

def test_allocator_admission_math_and_exhaustion():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=2,
                       max_blocks_per_slot=4)
    # prompt of 11 needs ceil(12/4) = 3 pages (prompt + first decode token)
    assert a.can_admit(11)
    a.alloc_slot(0, 11)
    assert a.blocks_held(0) == 3 and a.n_free == 1
    assert not a.can_admit(8)        # needs 3, only 1 free
    assert a.can_admit(2)            # needs 1
    # decode growth: position 12 opens block 3 — last free page
    assert a.ensure(0, 12)
    assert a.n_free == 0
    # pool dry: a fresh page cannot be mapped -> stall signal
    a.table[1, :] = -1
    assert not a.ensure(1, 0)
    # positions beyond the virtual row never need a mapping (trash-routed)
    assert a.ensure(1, 4 * 4)
    with pytest.raises(ValueError):
        a.alloc_slot(1, 11)          # alloc without capacity must raise


def test_allocator_free_on_evict_and_double_free():
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2,
                       max_blocks_per_slot=3)
    a.alloc_slot(0, 7)               # 2 pages
    a.alloc_slot(1, 3)               # 1 page
    assert a.in_use == 3 and a.peak_in_use == 3
    a.free_slot(0)
    assert a.in_use == 1 and a.n_free == 5
    assert (a.table[0] == -1).all()
    with pytest.raises(ValueError):
        a.free_slot(0)               # double free
    # freed pages are reusable immediately
    a.alloc_slot(0, 11)
    assert a.blocks_held(0) == 3
    assert a.peak_in_use == 4


def test_allocator_phys_row_routes_unmapped_to_trash():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=1,
                       max_blocks_per_slot=3)
    a.alloc_slot(0, 3)
    row = a.phys_row(0)
    assert row.shape == (3,) and row.dtype == np.int32
    assert row[0] == a.table[0, 0]
    assert (row[1:] == a.trash).all()


# ---------------------------------------------------------------------------
# Paged vs dense equivalence (the tentpole acceptance criterion).
# ---------------------------------------------------------------------------

PAGED_ARCHS = ["qwen3_1_7b", "seamless_m4t_large_v2", "zamba2_1_2b"]

N_SLOTS, MAX_LEN, MAX_PROMPT, BLOCK = 3, 40, 16, 8


@pytest.fixture(scope="module", params=PAGED_ARCHS)
def served_arch(request):
    cfg = registry.get_smoke_config(request.param)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)

    rs = np.random.RandomState(0)
    shapes = [(int(rs.randint(3, MAX_PROMPT)), int(rs.randint(3, 8)))
              for _ in range(3 * N_SLOTS)]   # 3x slots -> slot reuse
    fes = [jax.random.normal(
               jax.random.fold_in(jax.random.PRNGKey(7), i),
               (1, cfg.n_frontend_tokens or 16, cfg.d_model))
           if cfg.family == "encdec" else None
           for i in range(len(shapes))]

    def make_requests():
        rs2 = np.random.RandomState(1)
        return [Request(rid=i,
                        prompt=rs2.randint(0, cfg.vocab_size,
                                           size=plen).tolist(),
                        max_new_tokens=budget, frontend_embeds=fes[i])
                for i, (plen, budget) in enumerate(shapes)]

    dense_reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT)
    eng.run(dense_reqs, max_ticks=600)
    assert all(r.done for r in dense_reqs)
    return cfg, model, params, make_requests, dense_reqs, eng.cache_bytes


def test_paged_matches_dense_full_pool(served_arch):
    """Dense-parity pool: every stream identical, no stalls possible."""
    cfg, model, params, make_requests, dense_reqs, _ = served_arch
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, paged=True, block_size=BLOCK)
    eng.run(reqs, max_ticks=600)
    for d, p in zip(dense_reqs, reqs):
        assert p.generated == d.generated, (
            f"rid={d.rid}: paged {p.generated} != dense {d.generated}")
        assert p.finish_reason == d.finish_reason
    assert eng.stats["preempted"] == 0


def test_paged_matches_dense_tight_pool(served_arch):
    """A pool well below dense parity (here 7 pages vs 15) must still
    reproduce every stream exactly — admission gating and decode stalls
    only reshuffle timing, never tokens — while holding strictly less
    cache memory than the dense slabs."""
    cfg, model, params, make_requests, dense_reqs, dense_bytes = served_arch
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, paged=True, block_size=BLOCK,
                 n_blocks=7)
    eng.run(reqs, max_ticks=1200)
    for d, p in zip(dense_reqs, reqs):
        assert p.generated == d.generated, (
            f"rid={d.rid}: paged {p.generated} != dense {d.generated}")
    assert eng.stats["preempted"] == 0
    assert eng.allocator.peak_in_use <= 7
    assert eng.cache_bytes < dense_bytes


def test_paged_pool_exhaustion_queues_not_admits(served_arch):
    """With pages for only one live request, the second must wait QUEUED
    (never half-admitted) and still complete after the first frees its
    pages.  Pool of 3: r0 admits with ceil(16/8)=2 pages and grows to 3
    while decoding; r1 (also needing 2) stays queued until r0 finishes."""
    cfg, model, params, make_requests, _, _ = served_arch
    fe = make_requests()[0].frontend_embeds
    rs = np.random.RandomState(3)
    reqs = [Request(rid=100 + i,
                    prompt=rs.randint(0, cfg.vocab_size, size=15).tolist(),
                    max_new_tokens=7, frontend_embeds=fe)
            for i in range(2)]
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, paged=True, block_size=BLOCK,
                 n_blocks=3)
    for r in reqs:
        eng.submit(r)
    eng.tick()
    assert reqs[0].status is RequestStatus.ACTIVE
    assert reqs[1].status is RequestStatus.QUEUED   # pool full: not admitted
    ticks = 0
    while eng.scheduler.has_work:
        eng.tick()
        ticks += 1
        assert ticks < 600
    assert all(r.done for r in reqs)
    assert all(r.finish_reason == "length" for r in reqs)
    assert eng.stats["preempted"] == 0


def test_paged_rejects_family_without_kv():
    cfg = registry.get_smoke_config("mamba2_1_3b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="no paged KV cache"):
        Engine(model, cfg, params, paged=True)
