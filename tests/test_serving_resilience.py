"""Overload resilience: preempt-and-requeue with recompute, deadline-aware
scheduling, head-of-line aging, and the graceful-degradation ladder.

The acceptance criterion throughout is the recompute guarantee: a greedy
request stream disturbed by preemption / deadline eviction / ladder
transitions is bit-identical to (or a prefix of) the undisturbed run —
resilience trades latency, never tokens."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_model
from repro.serving import Engine, FaultPlan, Request, RequestStatus
from repro.serving.scheduler import Scheduler
from repro.spec import ModelDraft


class FakeClock:
    """Deterministic wall clock the deadline tests advance by hand."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# Scheduler (host-side, no jax): EDF order, expiry, aging.
# ---------------------------------------------------------------------------

def test_scheduler_edf_then_priority_then_arrival():
    s = Scheduler(n_slots=3)
    a = Request(rid=0, prompt=[1])                      # no deadline
    b = Request(rid=1, prompt=[1], deadline_s=5.0)
    c = Request(rid=2, prompt=[1], deadline_s=1.0)
    for r in (a, b, c):
        r.t_submit = 0.0
        s.submit(r)
    order = [r.rid for _, r in s.admit()]
    assert order == [2, 1, 0]       # earliest deadline first, none last


def test_scheduler_priority_breaks_ties_then_fifo():
    s = Scheduler(n_slots=3)
    lo = Request(rid=0, prompt=[1], priority=0)
    hi = Request(rid=1, prompt=[1], priority=3)
    lo2 = Request(rid=2, prompt=[1], priority=0)
    for r in (lo, hi, lo2):
        s.submit(r)
    assert [r.rid for _, r in s.admit()] == [1, 0, 2]


def test_scheduler_expire_sweeps_only_past_deadline():
    s = Scheduler(n_slots=1)
    a = Request(rid=0, prompt=[1], deadline_s=1.0)
    b = Request(rid=1, prompt=[1], deadline_s=9.0)
    c = Request(rid=2, prompt=[1])
    for r in (a, b, c):
        r.t_submit = 0.0
        s.submit(r)
    gone = s.expire(2.0)
    assert [r.rid for r in gone] == [0]
    assert [r.rid for r in s.queue] == [1, 2]


def test_scheduler_aging_reserves_capacity_for_blocked_head():
    """A capacity-blocked head is skipped only ``age_limit`` times; past
    that the scheduler admits nobody else, so freed capacity accrues to
    the head instead of every later small request jumping it forever
    (the seed's unbounded-starvation bug)."""
    cap = [1]
    s = Scheduler(n_slots=1, admit_ok=lambda r: r.prompt_len <= cap[0],
                  window=4, age_limit=2)
    big = Request(rid=0, prompt=[0] * 5)
    s.submit(big)
    for i in range(1, 5):
        s.submit(Request(rid=100 + i, prompt=[0]))
    admitted = []
    for _ in range(2):              # skips 1, 2: smalls still pass the head
        adm = s.admit()
        assert len(adm) == 1
        admitted.append(adm[0][1].rid)
        s.release(adm[0][0])
    assert admitted == [101, 102]
    for _ in range(3):              # aged out: capacity reserved, nobody in
        assert s.admit() == []
    assert big.sched_skips > 2
    cap[0] = 5                      # capacity finally fits the head
    adm = s.admit()
    assert [r.rid for _, r in adm] == [0]
    assert big.sched_skips == 0     # admission resets the age


# ---------------------------------------------------------------------------
# Engine fixtures.
# ---------------------------------------------------------------------------

ARCHS = ["qwen3_1_7b", "zamba2_1_2b"]   # two pageable families


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in ARCHS:
        cfg = registry.get_smoke_config(name)
        model = get_model(cfg)
        out[name] = (cfg, model, model.init(jax.random.PRNGKey(0), cfg))
    return out


def _mk_requests(cfg, n=4, seed=5, max_new=10, **kw):
    rs = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rs.randint(0, cfg.vocab_size,
                                      size=int(rs.randint(4, 12))).tolist(),
                    max_new_tokens=max_new, **kw)
            for i in range(n)]


def _drain(eng, limit=600):
    ticks = 0
    while eng.has_work:
        eng.tick()
        ticks += 1
        assert ticks < limit, "engine failed to drain"
    return ticks


# ---------------------------------------------------------------------------
# Preempt-and-requeue with recompute (the tentpole acceptance criterion).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ARCHS)
def test_preempt_requeue_streams_bit_identical(zoo, name):
    """Every active slot is preempted mid-stream; after requeue +
    re-prefill the greedy streams and finish reasons match the
    undisturbed run exactly, and the pool comes back leak-free."""
    cfg, model, params = zoo[name]

    def build():
        return Engine(model, cfg, params, n_slots=2, max_len=64,
                      max_prompt_len=32, paged=True, block_size=8)

    base = _mk_requests(cfg)
    build().run(base, max_ticks=600)
    assert all(r.done for r in base)

    reqs = _mk_requests(cfg)
    eng = build()
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.tick()
    victims = [slot for slot, r in eng.scheduler.active() if not r.done]
    assert victims, "nothing active to preempt"
    for slot in victims:
        eng.preempt(slot)
    _drain(eng)
    assert eng.stats["requeued"] >= len(victims)
    preempted = [r for r in reqs if r.n_preemptions > 0]
    assert len(preempted) >= len(victims)
    for b, r in zip(base, reqs):
        assert r.generated == b.generated, (
            f"rid={r.rid}: preempted {r.generated} != base {b.generated}")
        assert r.finish_reason == b.finish_reason
    eng.allocator.audit()


def test_all_stalled_deadlock_requeues_not_kills(zoo):
    """Pool sized so both slots admit then deadlock on growth: the seed
    killed one with ``cache_full``; now the victim requeues, re-prefills
    once pages free up, and BOTH streams finish bit-identical to a
    roomy-pool run."""
    cfg, model, params = zoo["qwen3_1_7b"]
    rs = np.random.RandomState(7)
    prompts = [rs.randint(0, cfg.vocab_size, size=15).tolist()
               for _ in range(2)]

    def mk(**kw):
        return [Request(rid=i, prompt=p, max_new_tokens=10, **kw)
                for i, p in enumerate(prompts)]

    base = mk()
    Engine(model, cfg, params, n_slots=2, max_len=64, max_prompt_len=24,
           paged=True, block_size=8).run(base, max_ticks=600)

    reqs = mk()
    eng = Engine(model, cfg, params, n_slots=2, max_len=64,
                 max_prompt_len=24, paged=True, block_size=8, n_blocks=4)
    eng.run(reqs, max_ticks=600)
    assert eng.stats["requeued"] >= 1
    assert any(r.n_preemptions > 0 for r in reqs)
    for b, r in zip(base, reqs):
        assert r.generated == b.generated
        assert r.finish_reason == "length"
    eng.allocator.audit()
    assert eng.allocator.n_free == eng.allocator.n_blocks


def test_deadlock_without_requeue_budget_is_terminal(zoo):
    """Same deadlock with ``max_preemptions=0``: no victim may requeue, so
    one request is terminally evicted with ``preempted_limit`` — and its
    partial stream is still a clean prefix of the undisturbed run."""
    cfg, model, params = zoo["qwen3_1_7b"]
    rs = np.random.RandomState(7)
    prompts = [rs.randint(0, cfg.vocab_size, size=15).tolist()
               for _ in range(2)]

    base = [Request(rid=i, prompt=p, max_new_tokens=10)
            for i, p in enumerate(prompts)]
    Engine(model, cfg, params, n_slots=2, max_len=64, max_prompt_len=24,
           paged=True, block_size=8).run(base, max_ticks=600)

    reqs = [Request(rid=i, prompt=p, max_new_tokens=10, max_preemptions=0)
            for i, p in enumerate(prompts)]
    eng = Engine(model, cfg, params, n_slots=2, max_len=64,
                 max_prompt_len=24, paged=True, block_size=8, n_blocks=4)
    eng.run(reqs, max_ticks=600)
    assert eng.stats["requeued"] == 0
    evicted = [r for r in reqs if r.finish_reason == "preempted_limit"]
    survived = [r for r in reqs if r.finish_reason == "length"]
    assert len(evicted) == 1 and len(survived) == 1
    for b, r in zip(base, reqs):
        assert b.generated[:len(r.generated)] == r.generated
    eng.allocator.audit()


# ---------------------------------------------------------------------------
# Deadlines (virtual clock).
# ---------------------------------------------------------------------------

def test_deadline_timeout_queued_and_active(zoo):
    cfg, model, params = zoo["qwen3_1_7b"]
    clock = FakeClock()
    eng = Engine(model, cfg, params, n_slots=1, max_len=48,
                 max_prompt_len=16, clock=clock)
    rs = np.random.RandomState(2)
    hog = Request(rid=0, prompt=rs.randint(0, cfg.vocab_size,
                                           size=6).tolist(),
                  max_new_tokens=12, deadline_s=100.0)
    late = Request(rid=1, prompt=rs.randint(0, cfg.vocab_size,
                                            size=6).tolist(),
                   max_new_tokens=12, deadline_s=1.0)
    eng.submit(hog)
    eng.tick()
    assert hog.status is RequestStatus.ACTIVE
    eng.submit(late)                    # queued behind the hog
    clock.t = 2.0                       # past late's deadline, queued
    eng.tick()
    assert late.done and late.finish_reason == "timeout"
    assert late.generated == []         # no prefill burned on a dead SLO
    clock.t = 101.0                     # past hog's deadline, mid-stream
    eng.tick()
    assert hog.done and hog.finish_reason == "timeout"
    assert 0 < len(hog.generated) < 12  # partial stream kept
    assert eng.stats["timeout"] == 2


def test_engine_admits_earliest_deadline_first(zoo):
    cfg, model, params = zoo["qwen3_1_7b"]
    clock = FakeClock()
    eng = Engine(model, cfg, params, n_slots=1, max_len=48,
                 max_prompt_len=16, clock=clock)
    rs = np.random.RandomState(3)
    reqs = [Request(rid=i, prompt=rs.randint(0, cfg.vocab_size,
                                             size=5).tolist(),
                    max_new_tokens=4, deadline_s=d)
            for i, d in enumerate([None, 50.0, 5.0])]
    for r in reqs:
        eng.submit(r)
    eng.tick()
    assert reqs[2].status is RequestStatus.ACTIVE   # tightest deadline wins
    _drain(eng)
    assert all(r.finish_reason == "length" for r in reqs)


def test_deadline_preempts_slack_rich_active_request(zoo):
    """A queued request about to miss its deadline evicts-with-requeue the
    active request with the most slack; the victim's stream still matches
    its undisturbed run after readmission."""
    cfg, model, params = zoo["qwen3_1_7b"]
    rs = np.random.RandomState(4)
    hog_prompt = rs.randint(0, cfg.vocab_size, size=6).tolist()
    urgent_prompt = rs.randint(0, cfg.vocab_size, size=6).tolist()

    base = Request(rid=0, prompt=hog_prompt, max_new_tokens=10)
    Engine(model, cfg, params, n_slots=1, max_len=48,
           max_prompt_len=32).run([base], max_ticks=200)

    clock = FakeClock()
    eng = Engine(model, cfg, params, n_slots=1, max_len=48,
                 max_prompt_len=32, clock=clock)
    hog = Request(rid=0, prompt=hog_prompt, max_new_tokens=10)
    eng.submit(hog)
    eng.tick()
    urgent = Request(rid=1, prompt=urgent_prompt, max_new_tokens=4,
                     deadline_s=0.5)
    eng.submit(urgent)                  # t_submit = 0.0
    clock.t = 0.46                      # slack 0.04 < margin 0.05
    eng.tick()
    assert eng.stats["deadline_preempts"] == 1
    assert urgent.status is RequestStatus.ACTIVE
    assert hog.status is RequestStatus.QUEUED and hog.n_preemptions == 1
    _drain(eng)
    assert urgent.finish_reason == "length"
    assert hog.finish_reason == "length"
    assert hog.generated == base.generated


# ---------------------------------------------------------------------------
# Graceful-degradation ladder.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ladder_degrades_under_stragglers_and_recovers(zoo):
    """Simulated slow ticks push the watchdog past its threshold: the
    ladder shrinks speculation, then steps back up after sustained calm —
    and the greedy streams never change across any transition."""
    cfg, model, params = zoo["qwen3_1_7b"]

    def build(fault=None):
        return Engine(model, cfg, params, n_slots=2, max_len=64,
                      max_prompt_len=16, spec_k=4, fault=fault,
                      draft=ModelDraft(cfg, params=params),
                      degrade_down_after=2, degrade_up_after=3)

    base = _mk_requests(cfg, n=4, max_new=12)
    build().run(base, max_ticks=600)

    reqs = _mk_requests(cfg, n=4, max_new=12)
    fault = FaultPlan(slow_ticks=(4, 5, 6, 7), slow_extra_s=300.0)
    eng = build(fault)
    eng.run(reqs, max_ticks=600)
    assert eng.stats["degrade_down"] >= 1
    assert fault.injected["slow"] >= 2
    for _ in range(50):                 # idle ticks are calm: step back up
        if eng.degrade_level == "full":
            break
        eng.tick()
    assert eng.degrade_level == "full"
    assert eng.stats["degrade_up"] >= 1
    assert eng.spec_k_eff == eng.spec_k == 4
    for b, r in zip(base, reqs):
        assert r.generated == b.generated
        assert r.finish_reason == b.finish_reason


def test_shed_level_bounds_queue_and_drops_lowest_priority(zoo):
    cfg, model, params = zoo["qwen3_1_7b"]
    eng = Engine(model, cfg, params, n_slots=1, max_len=48,
                 max_prompt_len=16, queue_bound=2,
                 degrade_down_after=1, degrade_up_after=1000)
    rs = np.random.RandomState(6)

    def mk(rid, priority=0):
        return Request(rid=rid,
                       prompt=rs.randint(0, cfg.vocab_size,
                                         size=5).tolist(),
                       max_new_tokens=4, priority=priority)

    first = [mk(i) for i in range(5)]
    for r in first:
        eng.submit(r)                   # 1 admits, 4 queued > bound of 2
    eng.tick()
    assert eng.degrade_level == "shed"
    # at the shed rung a full queue rejects the lowest-priority newcomer...
    walkup = mk(100)
    eng.submit(walkup)
    assert walkup.done and walkup.finish_reason == "rejected"
    # ...but a high-priority newcomer displaces a queued peer instead
    vip = mk(101, priority=5)
    eng.submit(vip)
    assert vip.status is RequestStatus.QUEUED
    shed = [r for r in first if r.finish_reason == "rejected"]
    assert len(shed) == 1
    assert eng.stats["rejected"] == 2
    _drain(eng)
    for r in first + [vip]:
        if r.finish_reason != "rejected":
            assert r.finish_reason == "length"


# ---------------------------------------------------------------------------
# TTFT bookkeeping across requeues.
# ---------------------------------------------------------------------------

def test_requeue_preserves_first_token_mark(zoo):
    cfg, model, params = zoo["qwen3_1_7b"]
    clock = FakeClock()
    eng = Engine(model, cfg, params, n_slots=1, max_len=48,
                 max_prompt_len=32, paged=True, block_size=8, clock=clock)
    rs = np.random.RandomState(8)
    req = Request(rid=0, prompt=rs.randint(0, cfg.vocab_size,
                                           size=6).tolist(),
                  max_new_tokens=8)
    eng.submit(req)
    clock.t = 1.0
    eng.tick()
    assert req.t_first_token == 1.0
    eng.preempt(0)
    clock.t = 5.0
    _drain(eng)
    assert req.t_first_token == 1.0     # readmission must not move TTFT
    assert req.finish_reason == "length"
