"""Sharding rule resolution: divisibility fallbacks, axis uniqueness,
param/batch/cache spec construction."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as S


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def spec(mesh, shape, logical):
    return S.spec_for(mesh, shape, logical)


def test_spec_basic(mesh):
    assert spec(mesh, (64, 128), ("embed", "ffn")) == P("data", "model")


def test_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 7 is not divisible by any >1 axis... with size-1 axes everything
    # divides; use a synthetic check through the public helper instead:
    s = S.spec_for(mesh, (7, 128), ("embed", "ffn"))
    assert s == P("data", "model")  # size-1 axes always divide


def test_spec_axis_uniqueness(mesh):
    # expert weights: (E, D, F) with expert->model first claims "model";
    # ffn (also model) must be dropped.
    s = S.spec_for(mesh, (64, 128, 256), ("expert", "embed", "ffn"))
    assert s == P("model", "data", None)


def test_spec_leading_dims_unsharded(mesh):
    # stacked layer params: rule covers trailing dims only
    s = S.spec_for(mesh, (12, 64, 128), ("embed", "ffn"))
    assert s == P(None, "data", "model")


def test_param_specs_on_model_tree(mesh):
    from repro.configs import registry
    from repro.models import get_model
    import functools
    cfg = registry.get_smoke_config("qwen3_1_7b")
    model = get_model(cfg)
    abs_params = jax.eval_shape(
        functools.partial(model.init, cfg=cfg), jax.random.PRNGKey(0))
    specs = S.param_specs(abs_params, mesh)
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["wd"]["w"] == P(None, "model", "data")
    assert specs["final_norm"]["scale"] == P(None)


def test_sell_params_zero3_sharded(mesh):
    import dataclasses, functools
    from repro.configs import registry
    from repro.models import get_model
    cfg = dataclasses.replace(registry.get_smoke_config("qwen3_1_7b"),
                              sell_kind="acdc")
    model = get_model(cfg)
    abs_params = jax.eval_shape(
        functools.partial(model.init, cfg=cfg), jax.random.PRNGKey(0))
    specs = S.param_specs(abs_params, mesh)
    # (L, K, N) stacked diagonals -> N over "data" (the "sell" logical axis)
    assert specs["layers"]["attn"]["wo"]["sell"]["a"] == P(None, None, "data")


def test_batch_and_data_specs():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = S.data_specs(mesh, batch)
    assert specs["tokens"] == P(("data",), None) or specs["tokens"] == P("data", None)


def test_multi_pod_batch_axes():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = S.data_specs(mesh, batch)
    assert specs["tokens"][0] == ("pod", "data")


def test_cache_specs_heads_divisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((4, 8, 128, 16, 32), jnp.bfloat16)}
    specs = S.cache_specs(cache, mesh)
    assert specs["k"] == P(None, ("data",), None, "model", None) or \
        specs["k"][3] == "model"


def test_cache_specs_seq_fallback_when_batch_unshardable():
    """batch=1 long-context: sequence axis takes the data shards."""
    mesh = jax.sharding.AbstractMesh((2, 1), ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((4, 1, 128, 16, 32), jnp.bfloat16)}
    specs = S.cache_specs(cache, mesh)
    assert specs["k"][1] is None
    assert specs["k"][2] in ("data", ("data",))


def test_missing_mesh_axis_dropped():
    """Rules referencing 'pod' resolve cleanly on a pod-less mesh."""
    mesh = jax.sharding.AbstractMesh((2,), ("data",))
    s = S.spec_for(mesh, (8, 16), ("batch", None))
    assert s == P(("data",), None) or s == P("data", None)
