"""Speculative decoding: the tentpole acceptance criteria.

* greedy spec streams are BIT-IDENTICAL to the non-speculative engine for
  every pageable family, dense AND paged — including under a garbage
  draft (maximal rollback, crossing page boundaries) and a perfect draft
  (full acceptance, bonus-token path);
* rejection sampling preserves the target sampling distribution;
* truncated-cascade self-drafting: acceptance > 0.5 at half depth on the
  ACDC smoke model and monotone in draft depth;
* rollback plumbing: allocator verify-window mapping and tail-page trim,
  the paged admission lookahead window, and the stalled-slot SSM freeze.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import registry
from repro.models import get_model
from repro.serving import BlockAllocator, Engine, Request, Scheduler
from repro.spec import ModelDraft, TruncatedCascadeDraft
from repro.spec import verify as verify_mod

SPEC_ARCHS = ["qwen3_1_7b", "seamless_m4t_large_v2", "zamba2_1_2b"]

N_SLOTS, MAX_LEN, MAX_PROMPT, SPEC_K = 2, 40, 16, 3


def _junk_draft_cfg(cfg):
    """A cheap draft config whose logits genuinely differ from the target
    (fresh params, fewer layers) — maximal rejection/rollback stress."""
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=1, n_encoder_layers=1)
    return dataclasses.replace(cfg, n_layers=max(1, cfg.n_layers - 1))


@pytest.fixture(scope="module", params=SPEC_ARCHS)
def served_arch(request):
    cfg = registry.get_smoke_config(request.param)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)

    rs = np.random.RandomState(0)
    shapes = [(int(rs.randint(3, MAX_PROMPT)), int(rs.randint(3, 9)))
              for _ in range(3 * N_SLOTS)]   # 3x slots -> slot reuse
    fes = [jax.random.normal(
               jax.random.fold_in(jax.random.PRNGKey(7), i),
               (1, cfg.n_frontend_tokens or 16, cfg.d_model))
           if cfg.family == "encdec" else None
           for i in range(len(shapes))]

    def make_requests():
        rs2 = np.random.RandomState(1)
        return [Request(rid=i,
                        prompt=rs2.randint(0, cfg.vocab_size,
                                           size=plen).tolist(),
                        max_new_tokens=budget, frontend_embeds=fes[i])
                for i, (plen, budget) in enumerate(shapes)]

    dense_reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT)
    eng.run(dense_reqs, max_ticks=600)
    assert all(r.done for r in dense_reqs)
    return cfg, model, params, make_requests, dense_reqs


def _assert_streams_equal(reqs, dense_reqs, tag):
    for d, s in zip(dense_reqs, reqs):
        assert s.generated == d.generated, (
            f"rid={d.rid} [{tag}]: spec {s.generated} != "
            f"dense {d.generated}")
        assert s.finish_reason == d.finish_reason


def test_spec_greedy_bit_identical_dense(served_arch):
    """Garbage draft, dense cache: every rejection rolls the slot back and
    the committed stream must still equal non-speculative greedy."""
    cfg, model, params, make_requests, dense_reqs = served_arch
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, spec_k=SPEC_K,
                 draft=ModelDraft(_junk_draft_cfg(cfg),
                                  rng=jax.random.PRNGKey(9)))
    eng.run(reqs, max_ticks=600)
    _assert_streams_equal(reqs, dense_reqs, "dense")
    assert eng.stats["drafted"] > 0


def test_spec_greedy_bit_identical_paged(served_arch):
    """Same under paging with 4-token pages: the k+1 verify window spans
    page boundaries every tick, so rollback repeatedly returns partially
    written tail pages to the pool."""
    cfg, model, params, make_requests, dense_reqs = served_arch
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, paged=True, block_size=4,
                 spec_k=SPEC_K,
                 draft=ModelDraft(_junk_draft_cfg(cfg),
                                  rng=jax.random.PRNGKey(9)))
    eng.run(reqs, max_ticks=600)
    _assert_streams_equal(reqs, dense_reqs, "paged")
    assert eng.stats["preempted"] == 0
    # rollback returned every over-mapped page: nothing leaks at drain
    assert eng.allocator.in_use == 0


def test_spec_perfect_draft_full_acceptance(served_arch):
    """A draft that IS the target accepts every token (the bonus-token
    path) and needs far fewer verify dispatches than tokens emitted."""
    cfg, model, params, make_requests, dense_reqs = served_arch
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, spec_k=SPEC_K,
                 draft=ModelDraft(cfg, params=params))
    eng.run(reqs, max_ticks=600)
    _assert_streams_equal(reqs, dense_reqs, "perfect")
    assert eng.stats["acceptance_rate"] == 1.0
    decode_tokens = eng.stats["tokens_out"] - len(reqs)  # minus prefill toks
    assert eng.stats["decode_ticks"] < decode_tokens


def test_spec_greedy_bit_identical_mamba2_dense():
    """The pure-SSM family has no paged cache but does have a verify path:
    dense spec decode with a garbage mamba2 draft must stay bit-identical
    (covers mamba2.verify_step on BOTH the target and the draft side —
    snapshot assembly, accepted-length commit, parked-row zero-commit)."""
    cfg = registry.get_smoke_config("mamba2_1_3b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.RandomState(2)
    mk = lambda: [Request(rid=i,
                          prompt=rs.randint(0, cfg.vocab_size,
                                            size=4 + i).tolist(),
                          max_new_tokens=5 + i)
                  for i in range(4)]
    rs = np.random.RandomState(2)
    dense_reqs = mk()
    rs = np.random.RandomState(2)
    reqs = mk()
    Engine(model, cfg, params, n_slots=2, max_len=MAX_LEN,
           max_prompt_len=MAX_PROMPT).run(dense_reqs, max_ticks=400)
    eng = Engine(model, cfg, params, n_slots=2, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, spec_k=SPEC_K,
                 draft=ModelDraft(_junk_draft_cfg(cfg),
                                  rng=jax.random.PRNGKey(9)))
    eng.run(reqs, max_ticks=400)
    _assert_streams_equal(reqs, dense_reqs, "mamba2")
    assert eng.stats["drafted"] > 0


def test_engine_draft_depth_zero_not_silently_defaulted():
    """`draft_depth=0` must surface the depth validation error, not be
    swallowed as falsy and replaced by the half-depth default."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen3_1_7b"),
                              sell_kind="acdc", sell_k=4,
                              sell_permute=False)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="depth 0"):
        Engine(model, cfg, params, n_slots=1, max_len=32, max_prompt_len=8,
               spec_k=2, draft_depth=0)


# ---------------------------------------------------------------------------
# Truncated-cascade self-drafting (the paper's depth result as a draft).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def acdc_target():
    """ACDC SELL smoke model (un-riffled, near-converged init scale — a
    trained cascade's tail is near identity, which is exactly what makes
    truncation a usable draft; riffled cascades truncate poorly, see
    spec/draft.py)."""
    cfg = dataclasses.replace(
        registry.get_smoke_config("qwen3_1_7b"), sell_kind="acdc",
        sell_k=4, sell_permute=False, sell_init_std=0.02)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.RandomState(0)
    shapes = [(int(rs.randint(4, MAX_PROMPT)), 10) for _ in range(4)]

    def make_requests():
        rs2 = np.random.RandomState(1)
        return [Request(rid=i,
                        prompt=rs2.randint(0, cfg.vocab_size,
                                           size=plen).tolist(),
                        max_new_tokens=budget)
                for i, (plen, budget) in enumerate(shapes)]

    dense_reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=2, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT)
    eng.run(dense_reqs, max_ticks=600)
    return cfg, model, params, make_requests, dense_reqs


def _acceptance_at_depth(acdc_target, depth):
    cfg, model, params, make_requests, dense_reqs = acdc_target
    reqs = make_requests()
    eng = Engine(model, cfg, params, n_slots=2, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, spec_k=4,
                 draft=TruncatedCascadeDraft(cfg, params, depth=depth))
    eng.run(reqs, max_ticks=600)
    _assert_streams_equal(reqs, dense_reqs, f"depth={depth}")
    return eng.stats["acceptance_rate"]


def test_truncated_cascade_half_depth_acceptance(acdc_target):
    """The acceptance criterion: K_draft = K/2 accepts > 0.5 of drafts."""
    assert _acceptance_at_depth(acdc_target, 2) > 0.5


def test_truncated_cascade_acceptance_monotone_in_depth(acdc_target):
    """Deeper truncations approximate the target better (sections 3-4
    depth result): acceptance rises with draft depth, reaching exactly
    1.0 at full depth (the draft IS the target)."""
    a1 = _acceptance_at_depth(acdc_target, 1)
    a2 = _acceptance_at_depth(acdc_target, 2)
    a4 = _acceptance_at_depth(acdc_target, 4)
    assert a1 <= a2 + 1e-9 <= a4 + 2e-9
    assert a4 == 1.0


def test_truncated_cascade_skip_top_layers(acdc_target):
    """skip_layers drops top transformer blocks from the draft on top of
    cascade truncation; streams stay exact regardless."""
    cfg, model, params, make_requests, dense_reqs = acdc_target
    reqs = make_requests()
    draft = TruncatedCascadeDraft(cfg, params, depth=2, skip_layers=1)
    assert draft.cfg.n_layers == cfg.n_layers - 1
    eng = Engine(model, cfg, params, n_slots=2, max_len=MAX_LEN,
                 max_prompt_len=MAX_PROMPT, spec_k=3, draft=draft)
    eng.run(reqs, max_ticks=600)
    _assert_streams_equal(reqs, dense_reqs, "skip_layers")


def test_model_draft_rejects_vocab_mismatch():
    cfg = registry.get_smoke_config("qwen3_1_7b")
    other = dataclasses.replace(cfg, vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab"):
        ModelDraft(other, rng=jax.random.PRNGKey(0), target_cfg=cfg)


# ---------------------------------------------------------------------------
# Rejection sampling preserves the target distribution.
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=2**16))
def test_rejection_sampling_preserves_target_distribution(seed):
    """Whatever the draft proposes, the FIRST committed token of a spec
    step is distributed as the target: run the accept/resample math over
    thousands of independent keys (vectorized as batch rows) with drafts
    genuinely sampled from the draft distribution, and compare the
    empirical marginal to the target softmax in total variation."""
    vocab, k, n_rows = 5, 2, 4000
    rng = np.random.RandomState(seed)
    t_logits = jnp.asarray(
        np.broadcast_to(rng.randn(1, k + 1, vocab) * 1.5,
                        (n_rows, k + 1, vocab)))
    d_logits = jnp.asarray(
        np.broadcast_to(rng.randn(1, k, vocab) * 1.5,
                        (n_rows, k, vocab)))
    key = jax.random.PRNGKey(seed)
    dk, ak = jax.random.split(key)
    # drafts MUST be samples from the draft distribution (the algorithm's
    # precondition): one independent draw per row and position
    drafts = jax.random.categorical(
        dk, jnp.broadcast_to(d_logits, (n_rows, k, vocab)),
        axis=-1).astype(jnp.int32)
    # independent accept/resample randomness per row
    n, nxt = jax.vmap(
        lambda r, lg, dlg, dr: verify_mod.rejection_accept(
            r, lg[None], dlg[None], dr[None]),
    )(jax.random.split(ak, n_rows), t_logits, d_logits, drafts)
    n = np.asarray(n)[:, 0]
    nxt = np.asarray(nxt)[:, 0]
    drafts_np = np.asarray(drafts)
    first = np.where(n >= 1, drafts_np[:, 0], nxt)
    emp = np.bincount(first, minlength=vocab) / n_rows
    target = np.asarray(jax.nn.softmax(t_logits[0, 0]))
    tv = 0.5 * np.abs(emp - target).sum()
    assert tv < 0.06, f"total variation {tv:.3f} (emp={emp}, p={target})"


def test_greedy_accept_math():
    """Unit pin of the prefix-match rule and correction/bonus selection."""
    logits = jnp.asarray(np.eye(4, dtype=np.float32)[
        np.array([[2, 0, 3, 1], [1, 2, 0, 3]])])       # argmax per position
    drafts = jnp.asarray([[2, 0, 0], [0, 2, 0]], jnp.int32)
    n, nxt = verify_mod.greedy_accept(logits, drafts)
    # row 0: d1=2==argmax(L0), d2=0==argmax(L1), d3=0!=argmax(L2)=3 -> n=2
    # row 1: d1=0!=argmax(L0)=1 -> n=0, correction=argmax(L0)=1
    assert n.tolist() == [2, 0]
    assert nxt.tolist() == [3, 1]
    out = verify_mod.committed_tokens(drafts, n, nxt)
    assert out[0, :3].tolist() == [2, 0, 3]
    assert out[1, 0].tolist() == 1


# ---------------------------------------------------------------------------
# Rollback plumbing: allocator, scheduler lookahead, stall freeze.
# ---------------------------------------------------------------------------

def test_allocator_ensure_range_all_or_nothing():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=2,
                       max_blocks_per_slot=4)
    a.alloc_slot(0, 7)                     # pages 0..1 (positions 0..7)
    assert a.n_free == 2
    # verify window 8..12 needs pages 2 and 3: both free -> mapped
    assert a.ensure_range(0, 8, 5)
    assert a.blocks_held(0) == 4 and a.n_free == 0
    a.free_slot(0)
    a.alloc_slot(0, 7)
    a.alloc_slot(1, 7)                     # pool empty again
    # window needs 2 pages, 0 free: nothing may stick
    assert not a.ensure_range(0, 8, 5)
    assert a.blocks_held(0) == 2 and a.n_free == 0
    # beyond the virtual row length needs no mapping
    assert a.ensure_range(0, 4 * 4, 3)


def test_allocator_trim_returns_tail_pages():
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=1,
                       max_blocks_per_slot=6)
    a.alloc_slot(0, 7)                     # 2 pages
    assert a.ensure_range(0, 8, 8)         # verify window maps pages 2,3
    assert a.blocks_held(0) == 4
    # commit lands at 10 tokens -> ceil(10/4)=3 pages stay, 1 returns
    assert a.trim_slot(0, 10) == 1
    assert a.blocks_held(0) == 3 and a.n_free == 3
    # trimming an already-tight slot is a no-op
    assert a.trim_slot(0, 10) == 0
    # freed page is immediately remappable
    assert a.ensure(0, 12)
    # engine convention: trim at frontier+1 so a page-boundary frontier
    # keeps the page its next write needs instead of churning it
    assert a.trim_slot(0, 13) == 0
    assert a.trim_slot(0, 12) == 1


def test_scheduler_lookahead_window_unblocks_small_requests():
    """A capacity-blocked head no longer starves the queue: the first of
    the next W queued requests that fits is admitted; beyond the window
    nothing is considered; queue order is otherwise preserved."""
    fits = lambda r: r.prompt_len <= 4
    sch = Scheduler(2, admit_ok=fits, window=3)
    big = Request(rid=0, prompt=[1] * 10)
    small1 = Request(rid=1, prompt=[1] * 3)
    small2 = Request(rid=2, prompt=[1] * 3)
    for r in (big, small1, small2):
        sch.submit(r)
    admitted = sch.admit(limit=1)
    assert [r.rid for _, r in admitted] == [1]     # head skipped, not lost
    assert [r.rid for r in sch.queue] == [0, 2]
    # window=1 restores strict FIFO blocking
    sch2 = Scheduler(2, admit_ok=fits, window=1)
    for r in (Request(rid=0, prompt=[1] * 10), Request(rid=1, prompt=[1] * 3)):
        sch2.submit(r)
    assert sch2.admit() == []
    # beyond the window nothing is admitted either
    sch3 = Scheduler(2, admit_ok=fits, window=2)
    for rid, plen in ((0, 10), (1, 10), (2, 3)):
        sch3.submit(Request(rid=rid, prompt=[1] * plen))
    assert sch3.admit() == []


def test_paged_admission_no_head_of_line_blocking():
    """End-to-end regression: a large head request that does not fit the
    free pool no longer starves smaller ones behind it — they are served
    first and the head completes once pages free up."""
    cfg = registry.get_smoke_config("qwen3_1_7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    # pool of 4 4-token pages; the 12-token request needs all 4 at once
    eng = Engine(model, cfg, params, n_slots=2, max_len=32,
                 max_prompt_len=12, paged=True, block_size=4, n_blocks=4)
    first = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=2)
    big = Request(rid=1, prompt=list(range(1, 13)), max_new_tokens=2)
    small = Request(rid=2, prompt=[2, 7, 1], max_new_tokens=2)
    for r in (first, big, small):
        eng.submit(r)
    eng.tick()
    # `first` holds a page, so `big` (queue head) cannot map its 4 — but
    # `small` behind it is admitted instead of waiting on the head
    assert small.status.value == "active" or small.done
    assert big.status.value == "queued"            # skipped, not starved out
    ticks = 0
    while eng.scheduler.has_work:
        eng.tick()
        ticks += 1
        assert ticks < 200
    assert big.done and small.done and first.done
    assert eng.stats["preempted"] == 0


def test_zamba2_stalled_slot_freezes_ssm_state():
    """Regression: a stalled paged slot parks its KV write on the trash
    page but used to keep advancing its Mamba SSM/conv state, consuming
    the pending token twice once the stall cleared.  The stream after a
    real stall must equal the dense engine's."""
    cfg = registry.get_smoke_config("zamba2_1_2b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    mk = lambda: [Request(rid=0, prompt=list(range(1, 6)), max_new_tokens=6),
                  Request(rid=1, prompt=list(range(1, 8)), max_new_tokens=6)]
    dense = mk()
    Engine(model, cfg, params, n_slots=2, max_len=40,
           max_prompt_len=16).run(dense, max_ticks=600)
    paged = mk()
    eng = Engine(model, cfg, params, n_slots=2, max_len=40,
                 max_prompt_len=16, paged=True, block_size=4, n_blocks=5)
    eng.run(paged, max_ticks=1200)
    assert eng.stats["stalled_slot_ticks"] > 0, "scenario must stall"
    assert eng.stats["preempted"] == 0
    for d, p in zip(dense, paged):
        assert p.generated == d.generated, (
            f"rid={d.rid}: stalled stream {p.generated} != {d.generated}")
