"""End-to-end behaviour tests for the paper's system: the ACDC layer as a
drop-in FC replacement inside a real model + elastic utilities."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.dist.elastic import ElasticPolicy, StragglerMonitor
from repro.models import get_model


def test_acdc_drop_in_replacement_changes_only_projections():
    """Same arch, dense vs ACDC: identical logits SHAPE and finiteness,
    massively fewer projection parameters — the paper's core promise."""
    cfg_d = registry.get_smoke_config("qwen3_1_7b")
    cfg_a = dataclasses.replace(cfg_d, sell_kind="acdc", sell_k=2)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0,
                              cfg_d.vocab_size)
    for cfg in (cfg_d, cfg_a):
        m = get_model(cfg)
        p = m.init(jax.random.PRNGKey(0), cfg)
        out = m.apply(p, toks, cfg)
        assert out.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.isfinite(out).all())


def test_elastic_policy_shrink_to_heal():
    pol = ElasticPolicy(model_parallel=16)
    assert pol.resolve_mesh(512) == (32, 16)
    assert pol.resolve_mesh(256) == (16, 16)
    assert pol.resolve_mesh(255) == (8, 16)   # lost a chip -> shrink data
    assert pol.resolve_mesh(16) == (1, 16)
    assert pol.resolve_mesh(8) == (1, 8)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.5, factor=3.0)
    for step in range(5):
        assert not mon.observe(step, 1.0)
    assert mon.observe(5, 10.0)
    assert mon.flagged == [5]
    # outlier did not poison the EWMA
    assert abs(mon.ewma - 1.0) < 1e-6


def test_skip_rules_match_design():
    assert registry.skips("deepseek_67b", "long_500k") is not None
    assert registry.skips("mamba2_1_3b", "long_500k") is None
    assert registry.skips("gemma3_27b", "long_500k") is None
    assert registry.skips("zamba2_1_2b", "long_500k") is None
    assert len(registry.cells()) == 33
    assert len(registry.cells(include_skipped=True)) == 40
