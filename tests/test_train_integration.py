"""End-to-end integration: launcher builds, trains, loss decreases,
checkpoint-resume is bit-exact-ish, accumulation matches big batch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.dist import steps as steps_mod
from repro.configs import registry
from repro.models import get_model
from repro.optim import OptimizerConfig, constant_schedule, make_optimizer


def _setup(arch="qwen3_1_7b", sell="dense", accum=1):
    import dataclasses
    cfg = registry.get_smoke_config(arch)
    if sell != "dense":
        cfg = dataclasses.replace(cfg, sell_kind=sell)
    model = get_model(cfg)
    opt = make_optimizer(OptimizerConfig(lr=1e-3, weight_decay=0.0),
                         constant_schedule(1e-3))
    step = jax.jit(steps_mod.make_train_step(model, cfg, opt, accum))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=4))
    state = steps_mod.init_state(model, cfg, opt, jax.random.PRNGKey(0))
    return cfg, model, opt, step, data, state


@pytest.mark.slow
def test_loss_decreases_dense_and_acdc():
    for sell in ("dense", "acdc"):
        cfg, model, opt, step, data, state = _setup(sell=sell)
        losses = []
        for i in range(30):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.2, (sell, first, last)


def test_grad_accumulation_matches_full_batch():
    cfg, model, opt, step1, data, state = _setup(accum=1)
    _, _, _, step4, _, _ = _setup(accum=4)
    batch = data.batch_at(0)
    s1, m1 = step1(state, batch)
    s4, m4 = step4(state, batch)
    # microbatched loss is the mean over microbatches == full-batch mean
    # (all microbatches have equal token counts here)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    w1 = jax.tree.leaves(s1["params"])[0]
    w4 = jax.tree.leaves(s4["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w4),
                               atol=5e-4, rtol=1e-3)


def test_train_step_determinism():
    cfg, model, opt, step, data, state = _setup()
    b = data.batch_at(0)
    s1, m1 = step(state, b)
    s2, m2 = step(state, b)
    assert float(m1["loss"]) == float(m2["loss"])


def test_sell_reduces_param_count_end_to_end():
    """ACDC projections shrink total model params (Table-1 mechanism)."""
    import dataclasses
    cfg_d = registry.get_smoke_config("qwen3_1_7b")
    cfg_a = dataclasses.replace(cfg_d, sell_kind="acdc", sell_k=2)
    md, ma = get_model(cfg_d), get_model(cfg_a)
    pd = md.init(jax.random.PRNGKey(0), cfg_d)
    pa = ma.init(jax.random.PRNGKey(0), cfg_a)
    nd = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pd))
    na = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pa))
    assert na < nd, (na, nd)


def test_compressed_grads_train_step():
    """make_train_step(compress_mesh=...): the int8 error-feedback gradient
    sync produces near-identical metrics to the plain step (blockwise
    quantization error is bounded by scale/2 per element), carries nonzero
    residuals in state, and keeps stepping with them."""
    from repro.launch.mesh import make_host_mesh

    cfg, model, opt, step, data, state = _setup()
    mesh = make_host_mesh()
    dp = dict(mesh.shape)["data"]
    cstep = jax.jit(steps_mod.make_train_step(model, cfg, opt, 1,
                                              compress_mesh=mesh))
    cstate = steps_mod.init_state(model, cfg, opt, jax.random.PRNGKey(0),
                                  compress_dp=dp)
    assert "grad_error" in cstate
    batch = data.batch_at(0)
    s1, m1 = step(state, batch)
    s2, m2 = cstep(cstate, batch)
    # loss is computed before the sync: identical
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    # grads only differ by the quantization error
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
        < 0.01 * float(m1["grad_norm"]) + 1e-6
    # residuals are live and the step keeps going with them
    assert any(float(jnp.abs(e).max()) > 0
               for e in jax.tree.leaves(s2["grad_error"]))
    s3, m3 = cstep(s2, data.batch_at(1))
    assert np.isfinite(float(m3["loss"]))
    assert int(s3["step"]) == 2


def test_compressed_launcher_smoke(tmp_path):
    """launch.train --compress-grads end-to-end on the host mesh."""
    from repro.launch import train as train_mod
    args = ["--arch", "qwen3_1_7b", "--smoke", "--steps", "2",
            "--seq-len", "32", "--global-batch", "2", "--ckpt-every", "0",
            "--ckpt-dir", str(tmp_path), "--log-every", "1",
            "--compress-grads"]
    train_mod.main(args)


def test_compressed_resume_reinit_residuals(tmp_path, capsys):
    """Elastic-safe resume of the compressed path: a checkpoint saved
    WITHOUT grad_error (compression enabled later) and one saved with a
    DIFFERENT data-parallel rank axis (elastic shrink) must both resume by
    re-zeroing residuals, never by mis-sharding stale ones."""
    from repro.checkpoint import CheckpointManager
    from repro.launch import train as train_mod

    def argv(steps, *extra):
        return ["--arch", "qwen3_1_7b", "--smoke", "--steps", str(steps),
                "--seq-len", "32", "--global-batch", "2", "--ckpt-every",
                "2", "--ckpt-dir", str(tmp_path), "--log-every", "1",
                *extra]

    # phase 1: checkpoint without compression
    train_mod.main(argv(2))
    # resume WITH compression: grad_error missing from the checkpoint
    train_mod.main(argv(4, "--resume", "--compress-grads"))
    assert CheckpointManager(str(tmp_path)).latest_step() == 4

    # phase 2: forge a wrong residual rank axis (as if saved on dp=2) and
    # resume on this dp=1 host mesh
    cfg, model, opt, _, _, _ = _setup()
    state = steps_mod.init_state(model, cfg, opt, jax.random.PRNGKey(0),
                                 compress_dp=2)
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(6, state)
    train_mod.main(argv(8, "--resume", "--compress-grads"))
    out = capsys.readouterr().out
    assert "resetting error feedback" in out
    assert CheckpointManager(str(tmp_path)).latest_step() == 8


def test_launcher_main_smoke(tmp_path):
    """launch.train.main runs, checkpoints, and resumes."""
    from repro.launch import train as train_mod
    args = ["--arch", "qwen3_1_7b", "--smoke", "--steps", "4",
            "--seq-len", "32", "--global-batch", "2",
            "--ckpt-every", "2", "--ckpt-dir", str(tmp_path),
            "--log-every", "2"]
    train_mod.main(args)
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(str(tmp_path)).latest_step() == 4
    train_mod.main(args + ["--resume"])  # no-op resume at final step
