"""Transform-layer unit + property tests (DCT, FWHT, permutations)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st
from scipy.fft import dct as scipy_dct, idct as scipy_idct

from repro.core import transforms as T


@pytest.mark.parametrize("n", [2, 4, 7, 16, 31, 64, 128, 256, 1000])
def test_dct_matches_scipy(n):
    x = np.random.RandomState(n).randn(3, n).astype(np.float32)
    ref = scipy_dct(x, type=2, norm="ortho", axis=-1)
    np.testing.assert_allclose(np.asarray(T.dct(jnp.asarray(x))), ref,
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(T.dct_via_matmul(jnp.asarray(x))),
                               ref, atol=5e-5)


@pytest.mark.parametrize("n", [4, 16, 31, 128, 513])
def test_idct_matches_scipy(n):
    x = np.random.RandomState(n).randn(2, n).astype(np.float32)
    ref = scipy_idct(x, type=2, norm="ortho", axis=-1)
    np.testing.assert_allclose(np.asarray(T.idct(jnp.asarray(x))), ref,
                               atol=5e-5)


@pytest.mark.parametrize("n", [8, 64, 256])
def test_dct_matrix_orthogonal(n):
    c = T._dct_matrix_np(n)  # float64 host-side matrix
    np.testing.assert_allclose(c @ c.T, np.eye(n), atol=1e-10)
    # and the device copy (fp32) is orthogonal to fp32 tolerance
    c32 = np.asarray(T.dct_matrix(n))
    np.testing.assert_allclose(c32 @ c32.T, np.eye(n), atol=1e-5)


@given(st.integers(2, 256), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_dct_roundtrip_property(n, seed):
    x = np.random.RandomState(seed).randn(2, n).astype(np.float32)
    rec = np.asarray(T.idct(T.dct(jnp.asarray(x))))
    np.testing.assert_allclose(rec, x, atol=1e-4)


@given(st.integers(2, 128), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_dct_parseval_property(n, seed):
    """Orthonormality <=> energy preservation."""
    x = np.random.RandomState(seed).randn(n).astype(np.float32)
    y = np.asarray(T.dct(jnp.asarray(x)))
    assert np.abs((y ** 2).sum() - (x ** 2).sum()) < 1e-3 * max(1, (x**2).sum())


@given(st.integers(2, 64), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_dct_linearity_property(n, seed):
    r = np.random.RandomState(seed)
    x, y = r.randn(n).astype(np.float32), r.randn(n).astype(np.float32)
    a = np.float32(r.randn())
    lhs = np.asarray(T.dct(jnp.asarray(a * x + y)))
    rhs = a * np.asarray(T.dct(jnp.asarray(x))) + np.asarray(T.dct(jnp.asarray(y)))
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)


@pytest.mark.parametrize("n", [2, 8, 64, 512])
def test_fwht_orthonormal_involution(n):
    x = np.random.RandomState(0).randn(2, n).astype(np.float32)
    y = T.fwht(jnp.asarray(x))
    rec = np.asarray(T.fwht(y))
    np.testing.assert_allclose(rec, x, atol=1e-4)  # H/sqrt(n) is involutive
    assert abs(float((jnp.asarray(y) ** 2).sum()) - float((x ** 2).sum())) < 1e-2


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        T.fwht(jnp.zeros((2, 12)))


@pytest.mark.parametrize("n", [2, 16, 128])
def test_fwht_normalized_involution_explicit(n):
    """fwht(fwht(x)) == x under normalize=True — the property the
    'hadamard' family relies on to reuse one function as both apply and
    inverse (H/sqrt(n) is orthonormal AND symmetric)."""
    x = np.random.RandomState(1).randn(3, n).astype(np.float32)
    rec = T.fwht(T.fwht(jnp.asarray(x), normalize=True), normalize=True)
    np.testing.assert_allclose(np.asarray(rec), x, atol=1e-4)


@pytest.mark.parametrize("n", [2, 8, 64])
def test_hadamard_matrix_matches_fwht(n):
    x = np.random.RandomState(2).randn(3, n).astype(np.float32)
    h = np.asarray(T.hadamard_matrix(n))
    np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(T.fwht(jnp.asarray(x))), x @ h, atol=1e-4)


def test_hadamard_matrix_rejects_non_pow2():
    with pytest.raises(ValueError):
        T.hadamard_matrix(12)


@pytest.mark.parametrize("n", [2, 4, 5, 8, 12, 16, 128])
def test_real_fft_matrix_orthonormal(n):
    f = np.asarray(T.real_fft_matrix(n), np.float64)
    np.testing.assert_allclose(f @ f.T, np.eye(n), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(T.real_ifft_matrix(n)), f.T.astype(np.float32),
        atol=1e-6)


@pytest.mark.parametrize("n", [4, 5, 8, 12, 16, 128])
def test_real_fft_matches_matrix(n):
    x = np.random.RandomState(3).randn(3, n).astype(np.float32)
    f = np.asarray(T.real_fft_matrix(n))
    got = np.asarray(T.real_fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, x @ f, atol=1e-4)


@pytest.mark.parametrize("n", [4, 5, 8, 12, 16, 128])
def test_real_fft_roundtrip(n):
    x = np.random.RandomState(4).randn(3, n).astype(np.float32)
    rec = np.asarray(T.real_ifft(T.real_fft(jnp.asarray(x))))
    np.testing.assert_allclose(rec, x, atol=1e-4)


@given(st.integers(2, 300))
@settings(max_examples=50, deadline=None)
def test_riffle_is_permutation(n):
    p = T.make_riffle(n)
    assert sorted(p.tolist()) == list(range(n))
    inv = T.invert_permutation(p)
    np.testing.assert_array_equal(p[inv], np.arange(n))


def test_dct_gradients_flow():
    def f(x):
        return jnp.sum(T.dct(x) ** 2)
    g = jax.grad(f)(jnp.ones((4, 16)))
    # orthonormal transform: grad of sum of squares is 2x
    np.testing.assert_allclose(np.asarray(g), 2 * np.ones((4, 16)), atol=1e-4)
